"""GNN example: train GCN and GAT on a synthetic Cora-sized graph using the
GraphBLAS segment substrate (message passing == SpMM over the adjacency).

    PYTHONPATH=src python examples/gnn_cora.py
"""

import jax
import jax.numpy as jnp

from repro.configs import gat_cora, gcn_cora
from repro.configs.base import make_gnn_train_step
from repro.data.graphs import random_graph
from repro.models.gnn import init_gnn

graph = random_graph(
    0, n_nodes=512, n_edges=2000, d_feat=64, n_classes=7,
    pad_edges=8192, with_coords=False,
)
batch = {k: jnp.asarray(v) for k, v in graph.batch_dict().items()}
shape = dict(d_feat=64, n_classes=7)

for mod in (gcn_cora, gat_cora):
    cfg = mod.make_cfg(shape)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    step, opt = make_gnn_train_step(cfg, "node", learning_rate=5e-3)
    state = {"params": params, "opt": opt.init(params)}
    step = jax.jit(step)
    accs = []
    for i in range(60):
        state, metrics = step(state, batch)
        accs.append(float(metrics["accuracy"]))
    print(f"{mod.ARCH_ID:10s} acc {accs[0]:.2f} -> {accs[-1]:.2f} "
          f"(loss {float(metrics['loss']):.3f})")
    assert accs[-1] > accs[0], "training did not improve accuracy"
