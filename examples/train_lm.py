"""End-to-end driver: train a reduced LM for a few hundred steps on CPU,
with checkpointing — kill it mid-run and rerun to see the restart path.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="llama3.2-1b")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
args = ap.parse_args()

train_main([
    "--arch", args.arch,
    "--preset", "smoke",          # reduced width/depth, same code paths
    "--steps", str(args.steps),
    "--global-batch", "8",
    "--seq-len", "128",
    "--ckpt-dir", args.ckpt_dir,
    "--ckpt-every", "50",
    "--log-every", "10",
])
