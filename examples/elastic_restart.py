"""Elastic restart walkthrough: train -> checkpoint -> lose devices ->
re-plan the mesh -> restore onto the new topology -> continue.

The checkpoint is topology-free (host numpy + structure), so restoring onto
a different mesh is just device_put with the new shardings — this script
exercises exactly the path a 512-chip run takes when a host dies and the
job restarts on 496 chips.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

from repro.checkpoint import CheckpointManager
from repro.distributed.fault import elastic_transition
from repro.launch.train import main as train_main

ckpt_dir = tempfile.mkdtemp(prefix="elastic_demo_")

# phase 1: "512-chip" run (locally: the dev-host mesh) trains and checkpoints
print("=== phase 1: initial run ===")
losses1 = train_main([
    "--arch", "qwen1.5-0.5b", "--preset", "smoke",
    "--steps", "20", "--global-batch", "8", "--seq-len", "64",
    "--ckpt-dir", ckpt_dir, "--ckpt-every", "10", "--log-every", "10",
])

# phase 2: the control plane loses 16 devices out of 512 and re-plans
print("\n=== phase 2: failure + re-plan (control plane) ===")
plan = elastic_transition(range(512), failed=range(16))
print(f"lost 16/512 devices -> new mesh {plan['mesh_shape']} "
      f"{plan['mesh_axes']}, {len(plan['idle'])} idle")
assert plan["mesh_shape"] == (31, 16)

# phase 3: restart picks up the latest checkpoint (params + optimizer +
# data-iterator position) and continues — the restore path re-shards onto
# whatever mesh the new job builds.
print("\n=== phase 3: restart & continue ===")
losses2 = train_main([
    "--arch", "qwen1.5-0.5b", "--preset", "smoke",
    "--steps", "30", "--global-batch", "8", "--seq-len", "64",
    "--ckpt-dir", ckpt_dir, "--ckpt-every", "10", "--log-every", "10",
])
assert len(losses2) == 10, "restart should resume at step 20, not 0"
print(f"\nresumed exactly at step 20; loss continued "
      f"{losses1[-1]:.3f} -> {losses2[-1]:.3f}")

mgr = CheckpointManager(ckpt_dir)
print(f"checkpoints retained: {mgr.steps()} (keep-N policy)")
