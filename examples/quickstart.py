"""Quickstart: build an anonymized hypersparse traffic matrix from a packet
stream and run the standard network analytics — the paper's pipeline in
~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics
from repro.core.window import WindowConfig, process_batch, window_slices
from repro.data.packets import zipf_traffic

# 1. traffic: heavy-tailed synthetic packets (the paper uses pktgen random
#    traffic; zipf is closer to real internet mixes)
rng = np.random.default_rng(0)
cfg = WindowConfig(window_log2=14, windows_per_batch=8,
                   anonymization="feistel", anonymization_key=0xC0FFEE)
packets = zipf_traffic(rng, cfg.windows_per_batch * cfg.window_size)

# 2. windows -> anonymized hypersparse matrices -> merged batch matrix
windows = window_slices(jnp.asarray(packets), cfg)
pipeline = jax.jit(lambda w: process_batch(w, cfg))
merged, per_window, overflow = pipeline(windows)
print(f"batch matrix: 2^32 x 2^32, nnz={int(merged.nnz):,} "
      f"(from {packets.shape[0]:,} packets; merge overflow {int(overflow)})")

# 3. GraphBLAS analytics on the anonymized matrix
stats = jax.jit(analytics.window_stats)(merged)
for k in ("valid_packets", "unique_links", "unique_sources",
          "unique_destinations", "max_packets_per_link",
          "max_source_fanout", "max_dest_fanin"):
    print(f"  {k:24s} {int(stats[k]):>12,}")

# 4. heavy hitters (anonymized IDs — the whole point: analytics without
#    seeing real addresses)
srcs, counts = analytics.top_k_sources(merged, 5)
print("top anonymized sources:",
      [(hex(int(s)), int(c)) for s, c in zip(srcs, counts)])
