"""The Suricata-flow workload, end to end: flow records with byte/packet
payloads stream through the value-carrying stage path
(anonymize_flows -> build_flow -> merge_flow -> analytics), with two
streaming sinks attached — per-window anomaly flagging (z-scored fan-out
histograms) and an anonymized pcap-lite replay capture.

    PYTHONPATH=src python examples/flow_ingest.py [--full]

A heavy-hitter scan is planted in one window; the AnomalySink must flag
exactly that window.  The script also checks payload conservation: the sum
of matrix values equals the sum of input byte/packet payloads (the plus
semiring conserves mass through build + merge).
"""

import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.core.window import WindowConfig
from repro.data.flows import FLOW_BYTES, FLOW_PKTS, FLOW_WIDTH
from repro.engine import (
    AnomalySink,
    IterableSource,
    MatrixRetention,
    PcapLiteWriterSink,
    StatsAccumulator,
    TrafficEngine,
)

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

geom = (dict(window_log2=13, windows_per_batch=8, n_batches=4)
        if args.full else dict(window_log2=8, windows_per_batch=4,
                               n_batches=2))
cfg = WindowConfig(window_log2=geom["window_log2"],
                   windows_per_batch=geom["windows_per_batch"])
W, n = cfg.windows_per_batch, cfg.window_size
print(f"geometry: 2^{geom['window_log2']} flows/window x {W} windows x "
      f"{geom['n_batches']} batches")

# Synthetic flow batches with a planted scan: one window where a single
# source fans out to every destination (the anomaly the z-score must find).
rng = np.random.default_rng(0)
batches = []
for b in range(geom["n_batches"]):
    flows = np.empty((W, n, FLOW_WIDTH), dtype=np.uint32)
    flows[..., :2] = rng.integers(0, 1 << 32, size=(W, n, 2))
    flows[..., FLOW_PKTS] = rng.integers(1, 64, size=(W, n))
    flows[..., FLOW_BYTES] = flows[..., FLOW_PKTS] * rng.integers(
        40, 1500, size=(W, n))
    flows[..., 4] = 2  # established
    batches.append(flows)
PLANTED = W + 1  # global window index (batch 1, window 1)
scan = batches[1][1]
scan[:, 0] = 0xC0FFEE  # one source...
scan[:, 1] = np.arange(n, dtype=np.uint32)  # ...sweeping every destination

pcap_path = Path(tempfile.gettempdir()) / "flow_replay.pcl"
# a z-score over N windows is bounded by sqrt(N-1), so the threshold must
# stay below sqrt(total windows - 1) to be reachable (2.5 < sqrt(7))
engine = TrafficEngine(
    cfg, workload="flow",
    sinks=[StatsAccumulator(), AnomalySink(threshold=2.5),
           PcapLiteWriterSink(path=pcap_path, key="flows"),
           MatrixRetention(max_keep=geom["n_batches"])],
)
report = engine.run(IterableSource(it=batches))
results = engine.finalize()

print(f"flow rate      : {report.packets_per_second:>12,.0f} flow/s "
      f"({report.packets:,} flows in {report.elapsed_s:.2f}s, "
      f"overflow {report.merge_overflow})")

# payload conservation through build-with-values + plus merge
total_pkts = sum(int(b[..., FLOW_PKTS].astype(np.int64).sum())
                 for b in batches)
matrix_pkts = 0
for m in results["matrices"]:
    valid = np.arange(m.rows.shape[0]) < int(m.nnz)
    matrix_pkts += int(np.asarray(m.vals)[valid].astype(np.int64).sum())
assert matrix_pkts == total_pkts, (matrix_pkts, total_pkts)
print(f"conservation   : sum(matrix) == sum(payloads) == {total_pkts:,}")

anomaly = results["anomaly"]
print(f"anomaly        : flagged windows {anomaly['flagged']} of "
      f"{anomaly['windows']} (planted: {PLANTED})")
assert anomaly["flagged"] == [PLANTED], anomaly["flagged"]

print(f"replay capture : {results['pcap']['packets']:,} anonymized "
      f"(src, dst) pairs -> {results['pcap']['path']}")
print("flow pipeline OK: planted scan flagged, payloads conserved")
