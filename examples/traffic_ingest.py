"""The paper's experiment, end to end: GraphBLAS-only vs GraphBLAS+IO
throughput (Fig. 2), on this host — driven through the unified
``repro.engine.TrafficEngine`` (Source -> Stage -> Sink, see DESIGN.md).

    PYTHONPATH=src python examples/traffic_ingest.py [--full]

--full uses the paper's exact geometry (2^17-packet windows, 64 windows x 8
batches); default is a fast reduced run.  Both execution policies consume
the same seeded source, so their per-batch analytics must agree exactly —
the script checks this (build correctness is policy-invariant; only the
schedule differs).
"""

import argparse

import numpy as np

from repro.core.window import WindowConfig
from repro.engine import StatsAccumulator, TrafficEngine

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--traffic", default="uniform", choices=["uniform", "zipf"])
args = ap.parse_args()

geom = (dict(window_log2=17, windows_per_batch=64, n_batches=8)
        if args.full else dict(window_log2=13, windows_per_batch=8,
                               n_batches=3))

print(f"geometry: 2^{geom['window_log2']} pkts/window x "
      f"{geom['windows_per_batch']} windows x {geom['n_batches']} batches")

cfg = WindowConfig(window_log2=geom["window_log2"],
                   windows_per_batch=geom["windows_per_batch"])


def run(policy):
    engine = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
    # one extra leading batch absorbs jit compile (excluded from timing)
    report = engine.run(args.traffic, n_batches=geom["n_batches"] + 1,
                        seed=0, warmup_items=1)
    return report, engine.finalize()["stats"]


rep_b, stats_b = run("blocking")
print(f"GraphBLAS only : {rep_b.packets_per_second:>12,.0f} pkt/s "
      f"({rep_b.packets:,} pkts in {rep_b.elapsed_s:.2f}s, "
      f"overflow {rep_b.merge_overflow})")

rep_s, stats_s = run("double_buffered")
print(f"GraphBLAS+IO   : {rep_s.packets_per_second:>12,.0f} pkt/s "
      f"({rep_s.packets:,} pkts in {rep_s.elapsed_s:.2f}s, "
      f"overflow {rep_s.merge_overflow})")

# same source, same stage graph => identical analytics under either policy
assert rep_b.packets == rep_s.packets
for a, b in zip(stats_b["per_batch"], stats_s["per_batch"]):
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
print("per-batch analytics identical across policies: OK")

print("\npaper (8 ARM cores): 18M pkt/s GraphBLAS-only, 8M pkt/s +IO;")
print("see EXPERIMENTS.md for the per-core comparison against this host.")
