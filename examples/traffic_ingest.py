"""The paper's experiment, end to end: GraphBLAS-only vs GraphBLAS+IO
throughput (Fig. 2), on this host.

    PYTHONPATH=src python examples/traffic_ingest.py [--full]

--full uses the paper's exact geometry (2^17-packet windows, 64 windows x 8
batches); default is a fast reduced run.
"""

import argparse

from repro.launch.ingest import run_paper_mode

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

geom = (dict(window_log2=17, windows_per_batch=64, n_batches=8)
        if args.full else dict(window_log2=13, windows_per_batch=8,
                               n_batches=3))

print(f"geometry: 2^{geom['window_log2']} pkts/window x "
      f"{geom['windows_per_batch']} windows x {geom['n_batches']} batches")

rep_b = run_paper_mode("blocking", **geom)
print(f"GraphBLAS only : {rep_b.packets_per_second:>12,.0f} pkt/s "
      f"({rep_b.packets:,} pkts in {rep_b.elapsed_s:.2f}s)")

rep_s = run_paper_mode("stream", **geom)
print(f"GraphBLAS+IO   : {rep_s.packets_per_second:>12,.0f} pkt/s "
      f"({rep_s.packets:,} pkts in {rep_s.elapsed_s:.2f}s)")

print("\npaper (8 ARM cores): 18M pkt/s GraphBLAS-only, 8M pkt/s +IO;")
print("see EXPERIMENTS.md for the per-core comparison against this host.")
