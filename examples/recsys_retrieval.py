"""Two-tower retrieval example: train with in-batch sampled softmax, then
serve a query against a candidate corpus (EmbeddingBag lookup = hypersparse
SpMM on the same kernels as the traffic matrices).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import two_tower
from repro.configs.base import make_recsys_train_step
from repro.models.recsys import init_two_tower, retrieve_topk

cfg = two_tower.smoke_config()
params = init_two_tower(jax.random.PRNGKey(0), cfg)
step, opt = make_recsys_train_step(cfg, learning_rate=3e-3)
state = {"params": params, "opt": opt.init(params)}
step = jax.jit(step)

rng = np.random.default_rng(0)
b = 64


def make_batch(i):
    r = np.random.default_rng(i)
    users = r.integers(0, cfg.user_vocab, (b, cfg.n_user_fields))
    # correlated items: positive item id derived from user field 0
    items = (users[:, :1] * 7 + r.integers(0, 3, (b, cfg.n_item_fields))) \
        % cfg.item_vocab
    return {
        "user_fields": jnp.asarray(users, jnp.int32),
        "history": jnp.asarray(
            r.integers(0, cfg.item_vocab, (b, cfg.history_len)), jnp.int32
        ),
        "history_len": jnp.full((b,), cfg.history_len, jnp.int32),
        "item_fields": jnp.asarray(items, jnp.int32),
        "log_q": jnp.zeros((b,), jnp.float32),
    }


accs = []
for i in range(80):
    state, metrics = step(state, make_batch(i))
    accs.append(float(metrics["in_batch_accuracy"]))
print(f"in-batch accuracy {np.mean(accs[:10]):.3f} -> "
      f"{np.mean(accs[-10:]):.3f}")
assert np.mean(accs[-10:]) > np.mean(accs[:10])

# retrieval: 1 query vs candidate corpus
query_batch = make_batch(999)
query = {k: v[:1] for k, v in query_batch.items()
         if k in ("user_fields", "history", "history_len")}
cands = jnp.asarray(
    rng.integers(0, cfg.item_vocab, (5000, cfg.n_item_fields)), jnp.int32
)
scores, idx = retrieve_topk(state["params"], query, cands, cfg, k=10)
print("top-10 candidate ids:", np.asarray(idx).tolist())
print("scores:", np.round(np.asarray(scores), 3).tolist())
