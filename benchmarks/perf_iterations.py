"""§Perf hillclimbing harness: named optimization variants for the three
chosen cells, re-lowered and re-analysed with the same machinery as the
baseline dry-run; each record lands in benchmarks/results_perf/.

Cells (chosen per the assignment criteria):
  * granite-3-8b x train_4k   — most collective-bound baseline (GQA KV
    resharding storm: involuntary SPMD remat + collective-permutes);
  * qwen2-moe-a2.7b x prefill_32k — worst memory fraction (77 GB/device:
    XLA replicates the global-sort MoE dispatch buffers);
  * traffic-matrix x ingest   — the paper's own technique.

Run:  PYTHONPATH=src python -m benchmarks.perf_iterations [--cell NAME]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results_perf"


def _lm_variant(arch_mod, shape, *, replicate_kv=False, remat_policy="full",
                attn_dtype="float32", ep_moe=False, mb_per_device=None,
                seq_parallel=False, grad_reduce_dtype=None):
    """Build a variant cell builder for an LM arch."""
    from repro.configs import base as cfg_base

    def build(shape_name, mesh, costing=False, costing_layers=None):
        cfg = arch_mod.model_config()
        changes = {}
        if remat_policy != "full":
            changes["remat_policy"] = remat_policy
        if attn_dtype != "float32":
            changes["attn_compute_dtype"] = attn_dtype
        if ep_moe and cfg.moe is not None:
            from repro.distributed.sharding import dp_axes

            changes["moe"] = dataclasses.replace(
                cfg.moe, expert_shard_map=True, dp_axes=dp_axes(mesh)
            )
        if seq_parallel:
            from repro.distributed.sharding import dp_axes

            changes["seq_parallel"] = True
            changes["dp_axes_for_sp"] = dp_axes(mesh)
        if changes:
            cfg = dataclasses.replace(cfg, **changes)
        # (lm_build_cell applies unroll_scans/costing_layers itself)
        mb = mb_per_device
        if mb is None:
            mb = {"granite-3-8b": 1, "qwen2-moe-a2.7b": 2}.get(
                cfg.name, 2
            )
        return cfg_base.lm_build_cell(
            cfg, shape_name, mesh, mb_per_device=mb, costing=costing,
            costing_layers=costing_layers, replicate_kv=replicate_kv,
            grad_reduce_dtype=grad_reduce_dtype,
        )

    return build


def _gnn_node_sharded(arch_mod):
    """Beyond-paper GNN variant: node arrays shard over `data` instead of
    replicating (the 86GB/device pna x ogb_products baseline replicates
    2.45M-node activations; sharding them trades all-gathers for memory)."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import base as cfg_base

    def build(shape_name, mesh, costing=False, costing_layers=None):
        del costing, costing_layers
        # node arrays must divide the data axis: pad the node count
        shape = dict(cfg_base.GNN_SHAPES[shape_name])
        shape["n_nodes"] = -(-shape["n_nodes"] // 512) * 512
        saved = cfg_base.GNN_SHAPES[shape_name]
        cfg_base.GNN_SHAPES[shape_name] = shape
        try:
            cell = cfg_base.gnn_build_cell(
                arch_mod.make_cfg, arch_mod.ARCH_ID, shape_name, mesh
            )
        finally:
            cfg_base.GNN_SHAPES[shape_name] = saved
        state_specs, bspecs = cell.in_specs
        for k in ("x", "labels", "label_mask"):
            if k in bspecs:
                bspecs[k] = P("data", *([None] * (len(
                    cell.args[1][k].shape) - 1)))
        return cell

    return build


def _traffic_variant(kind):
    from repro.configs import traffic_matrix as tm

    orig_build = tm.build_cell  # bind BEFORE the monkeypatch in run_variant

    def build(shape_name, mesh, costing=False, costing_layers=None):
        del costing, costing_layers
        return orig_build(kind, mesh)

    return build


def run_variant(arch_id, shape, mesh_kind, variant_name, builder):
    """run_cell with a substituted cell builder; JSON-cached."""
    from repro import configs
    from repro.launch import dryrun

    RESULTS.mkdir(parents=True, exist_ok=True)
    slug = f"{arch_id}__{shape}__{mesh_kind}__{variant_name}".replace(
        "/", "_"
    )
    path = RESULTS / f"{slug}.json"
    if path.exists():
        return json.loads(path.read_text())

    mod = configs.get(arch_id)
    orig = mod.build_cell
    mod.build_cell = builder
    try:
        rec = dryrun.run_cell(arch_id, shape, mesh_kind)
        rec["variant"] = variant_name
    except Exception as e:  # noqa: BLE001
        import traceback

        rec = {"arch": arch_id, "shape": shape, "mesh": mesh_kind,
               "variant": variant_name, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    finally:
        mod.build_cell = orig
    path.write_text(json.dumps(rec, indent=2))
    return rec


def summarize(rec):
    if rec.get("status") != "ok":
        return f"ERROR: {rec.get('error', '?')[:100]}"
    r = rec["roofline"]
    mem = rec.get("memory_per_device", {}).get("total_bytes", 0) / 1e9
    return (f"compute {r['compute_s']*1e3:8.2f}ms | "
            f"memory {r['memory_s']*1e3:9.2f}ms | "
            f"collective {r['collective_s']*1e3:8.2f}ms | "
            f"mem/dev {mem:6.2f}GB | dom {r['dominant']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None,
                    choices=[None, "granite", "moe", "phi", "traffic",
                             "gnn"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)

    from repro.configs import granite_3_8b, qwen2_moe_a2_7b

    plans = []
    if args.cell in (None, "granite"):
        plans += [
            ("granite-3-8b", "train_4k", "v1_replicate_kv",
             _lm_variant(granite_3_8b, "train_4k", replicate_kv=True)),
            ("granite-3-8b", "train_4k", "v2_repkv_dots_remat",
             _lm_variant(granite_3_8b, "train_4k", replicate_kv=True,
                         remat_policy="dots")),
            ("granite-3-8b", "train_4k", "v3_repkv_dots_bf16attn",
             _lm_variant(granite_3_8b, "train_4k", replicate_kv=True,
                         remat_policy="dots", attn_dtype="bfloat16")),
            ("granite-3-8b", "train_4k", "v4_seq_parallel",
             _lm_variant(granite_3_8b, "train_4k", seq_parallel=True)),
            ("granite-3-8b", "train_4k", "v5_sp_bf16grads",
             _lm_variant(granite_3_8b, "train_4k", seq_parallel=True,
                         grad_reduce_dtype="bfloat16")),
            ("granite-3-8b", "prefill_32k", "v6_prefill_replicate_kv",
             _lm_variant(granite_3_8b, "prefill_32k", replicate_kv=True)),
            ("granite-3-8b", "train_4k", "v7_sp_repkv",
             _lm_variant(granite_3_8b, "train_4k", seq_parallel=True,
                         replicate_kv=True)),
        ]
    if args.cell in (None, "moe"):
        plans += [
            ("qwen2-moe-a2.7b", "prefill_32k", "v1_ep_shard_map",
             _lm_variant(qwen2_moe_a2_7b, "prefill_32k", ep_moe=True)),
            ("qwen2-moe-a2.7b", "prefill_32k", "v2_ep_bf16attn",
             _lm_variant(qwen2_moe_a2_7b, "prefill_32k", ep_moe=True,
                         attn_dtype="bfloat16")),
            ("qwen2-moe-a2.7b", "train_4k", "v3_ep_train",
             _lm_variant(qwen2_moe_a2_7b, "train_4k", ep_moe=True)),
        ]
    if args.cell in (None, "phi"):
        from repro.configs import phi3_5_moe

        plans += [
            ("phi3.5-moe-42b-a6.6b", "train_4k", "v1_ep_shard_map",
             _lm_variant(phi3_5_moe, "train_4k", ep_moe=True,
                         mb_per_device=1)),
            ("phi3.5-moe-42b-a6.6b", "train_4k", "v2_ep_repkv_bf16",
             _lm_variant(phi3_5_moe, "train_4k", ep_moe=True,
                         replicate_kv=True, attn_dtype="bfloat16",
                         mb_per_device=1)),
        ]
    if args.cell in (None, "traffic"):
        plans += [
            ("traffic-matrix", "ingest_512w", "v1_exact_all_to_all",
             _traffic_variant("ingest_exact")),
            # v2: count-build fast path (no value payload through the sort;
            # run lengths from head positions) — now the default builder,
            # measured against the cached pre-change baseline record
            ("traffic-matrix", "ingest_512w", "v2_count_build",
             _traffic_variant("ingest_512w")),
            ("traffic-matrix", "ingest_exact", "v3_exact_plus_countbuild",
             _traffic_variant("ingest_exact")),
        ]
    if args.cell in (None, "gnn"):
        from repro.configs import pna as pna_mod

        plans += [
            ("pna", "ogb_products", "v1_node_sharded",
             _gnn_node_sharded(pna_mod)),
        ]

    for arch_id, shape, vname, builder in plans:
        print(f"=== {arch_id} x {shape} [{args.mesh}] :: {vname} ===",
              flush=True)
        rec = run_variant(arch_id, shape, args.mesh, vname, builder)
        print("   " + summarize(rec), flush=True)


if __name__ == "__main__":
    main()
