"""Roofline table: reads the dry-run JSON cache and renders EXPERIMENTS.md
§Roofline rows (all three terms, dominant bottleneck, MODEL_FLOPS ratio).

Also tracks the gap to the paper's 18M pkt/s peak from the recorded
``results_kernels/kernels_bench.json`` fused-build row, so the build-path
trajectory lives next to the mesh roofline in one table.

Run after  PYTHONPATH=src python -m repro.launch.dryrun .
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results"
RESULTS_KERNELS = Path(__file__).parent / "results_kernels"

# Paper Fig. 2 peak: 18M pkt/s aggregate over 8 ARM cores (2.25M/core).
PAPER_PEAK_PKT_PER_S = 18e6
PAPER_PER_CORE_PKT_PER_S = PAPER_PEAK_PKT_PER_S / 8


def fused_build_rows():
    """Gap-to-18M rows from the recorded fused-build microbench (empty if
    no sweep has been recorded yet — the roofline table degrades, never
    fails, without one)."""
    path = RESULTS_KERNELS / "kernels_bench.json"
    if not path.exists():
        return []
    record = json.loads(path.read_text())
    rows = []
    for r in record["rows"]:
        if not r["name"].startswith("build_fused_"):
            continue
        n_log2 = int(r["name"].rsplit("^", 1)[1])
        rate = (1 << n_log2) / (r["us"] / 1e6)
        rows.append((
            f"{r['name']}_gap_to_18M",
            r["us"],
            f"{rate / PAPER_PER_CORE_PKT_PER_S:.2f}x_paper_core_rate",
        ))
    return rows


def load_records(mesh: str | None = None):
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_table(recs, *, only_ok=True) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | model/HLO flops | mem/dev GB | compile_s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            if not only_ok:
                lines.append(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"ERROR: {r.get('error', '?')[:60]} | | | | | | |"
                )
            continue
        rf = r["roofline"]
        mem = r.get("memory_per_device", {}).get("total_bytes", 0) / 1e9
        ratio = r.get("model_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | **{rf['dominant']}** | "
            f"{ratio:.3f} | {mem:.2f} | {r.get('compile_s', 0):.0f} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | **{rf['dominant']}** | n/a | "
            f"{mem:.2f} | {r.get('compile_s', 0):.0f} |"
        )
    return "\n".join(lines)


def run():
    """benchmarks.run hook: one row per completed dry-run cell, plus the
    recorded fused-build gap-to-18M trajectory."""
    rows = fused_build_rows()
    for r in load_records():
        if r.get("status") != "ok":
            rows.append((
                f"dryrun_{r['arch']}_{r['shape']}_{r['mesh']}",
                -1.0, "ERROR",
            ))
            continue
        rf = r["roofline"]
        rows.append((
            f"dryrun_{r['arch']}_{r['shape']}_{r['mesh']}",
            rf["step_s_lower_bound"] * 1e6,
            rf["dominant"],
        ))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(fmt_table(recs, only_ok=False))
    errs = [r for r in recs if r.get("status") != "ok"]
    print(f"\n{len(recs) - len(errs)} ok / {len(errs)} errors")
