"""Fig. 2 variant for the flow-record workload: value-payload build+merge
throughput (flows/s instead of pkt/s).

The Suricata-flow path (Houle et al.) does strictly more work per record
than the packet path: values ride through the sort, the dup-accumulation is
a real segment reduction (no counting fast path), and the merge carries
payloads — so its curve sits below the packet curves and measures the cost
of value semirings.  Both policies run so the blocking vs double-buffered
split stays comparable with the packet Fig. 2 suites.
"""

from __future__ import annotations

import argparse

from repro.core.window import WindowConfig
from repro.engine import TrafficEngine


def run(window_log2: int = 15, windows_per_batch: int = 16,
        n_batches: int = 4, anonymization: str = "feistel",
        policies=("blocking", "double_buffered")):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)
    rows = []
    for policy in policies:
        # Build+merge only in the timed step, like the packet suites; the
        # packet-count payload path is what the merge semiring exercises.
        engine = TrafficEngine(
            cfg, workload="flow", policy=policy,
            stages=("anonymize_flows", "build_flow", "merge_flow"),
            outputs=("merge_overflow",),
        )
        rep = engine.run("uniform", n_batches=n_batches + 1, seed=0,
                         warmup_items=1)
        rows.append((
            f"fig2_flow_{policy}",
            rep.elapsed_s / max(rep.batches, 1) * 1e6,
            f"{rep.packets_per_second:,.0f}_flow_per_s",
        ))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", action="append", default=None,
                    help="repeatable; any registered engine policy "
                         "(default: blocking + double_buffered)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    kw = dict(window_log2=12, windows_per_batch=8,
              n_batches=2) if args.quick else {}
    if args.policy:
        kw["policies"] = tuple(args.policy)
    print("name,us_per_call,derived")
    for name, us, derived in run(**kw):
        print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
