"""Pallas-kernel microbenches (interpret mode on CPU — relative numbers;
the BlockSpec tiling is the TPU story, validated structurally).

The build-kernel rows decompose `matrix_build`'s hot loop so the fused
kernel's before/after is auditable stage by stage:

  sort_two_argsort   — the oracle's sort (two stable argsorts + gathers)
  sort_variadic      — the fused path's CPU sort stage (one lax.sort)
  dedup_jnp          — count_dedup_sorted on pre-sorted streams
  dedup_compact_pallas — the fused dedup+compact kernel on the same streams
  build_jnp          — whole matrix_build, use_kernel=False (the before)
  build_fused        — whole fused_build (the after)
  merge_sort_3argsort / merge_sort_variadic — the ewise_add merge-path
    sort before/after the lex_sort valid= fix (validity as a third key)

``python -m benchmarks.kernels_bench`` records the rows as JSON under
``benchmarks/results_kernels/`` (mirroring ``results_fig2/``); ``--quick``
shrinks n and writes a ``*_quick.json`` artifact so CI-sized runs never
clobber a recorded sweep.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = Path(__file__).parent / "results_kernels"


def _time(fn, *args, iters=3):
    out = jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def _build_rows(n_log2: int, iters: int = 3):
    """The sort/dedup/fused decomposition at one window size."""
    from repro.core.build import (
        count_dedup_sorted,
        lex_sort,
        matrix_build,
    )
    from repro.kernels.build_fused import kernel as fused_kernel
    from repro.kernels.build_fused import ops as fused_ops

    rng = np.random.default_rng(0)
    n = 1 << n_log2
    tag = f"2^{n_log2}"
    rows_a = jnp.asarray(rng.integers(0, 1 << 32, n, dtype=np.uint32))
    cols_a = jnp.asarray(rng.integers(0, 1 << 32, n, dtype=np.uint32))
    out = []

    # -- sort-only: the oracle's two argsorts vs the fused variadic sort
    us_two, (sr, sc) = _time(
        jax.jit(lambda r, c: lex_sort(r, c)), rows_a, cols_a, iters=iters
    )
    us_var, _ = _time(
        jax.jit(lambda r, c: jax.lax.sort((r, c), num_keys=2,
                                          is_stable=True)),
        rows_a, cols_a, iters=iters,
    )
    out.append((f"sort_two_argsort_{tag}", us_two, "oracle_sort"))
    out.append((f"sort_variadic_{tag}", us_var,
                f"{us_two / us_var:.2f}x_vs_argsort"))

    # -- dedup-only on the pre-sorted streams
    nv = jnp.int32(n)
    us_dj, _ = _time(
        jax.jit(lambda r, c: count_dedup_sorted(r, c, jnp.int32(n))),
        sr, sc, iters=iters,
    )
    iota = jnp.arange(n, dtype=jnp.int32)
    key_change = jnp.concatenate(
        [(sr[:-1] != sr[1:]) | (sc[:-1] != sc[1:]),
         jnp.ones((1,), jnp.bool_)]
    )
    closes = ((iota < nv) & (key_change | (iota == nv - 1))).astype(jnp.int32)
    starts = jnp.concatenate([jnp.ones((1,), jnp.int32), closes[:-1]])
    ones = jnp.ones((n,), jnp.int32)
    bs = max(128, -(-n // 128) * 128) if n <= 131072 else 131072
    us_dk, _ = _time(
        lambda r, c, v, s, cl: fused_kernel.dedup_compact(
            r, c, v, s, cl, block_size=bs, interpret=True
        ),
        sr, sc, ones, starts, closes, iters=iters,
    )
    out.append((f"dedup_jnp_{tag}", us_dj, "oracle_dedup"))
    out.append((f"dedup_compact_pallas_{tag}", us_dk,
                f"{us_dj / us_dk:.2f}x_vs_jnp"))

    # -- the whole build: before (jnp oracle) / after (fused kernel)
    us_bj, _ = _time(
        lambda r, c: matrix_build(r, c), rows_a, cols_a, iters=iters
    )
    us_bf, _ = _time(
        lambda r, c: fused_ops.fused_build(r, c), rows_a, cols_a,
        iters=iters,
    )
    rate = n / (us_bf / 1e6)
    out.append((f"build_jnp_{tag}", us_bj, "oracle_build"))
    out.append((f"build_fused_{tag}", us_bf,
                f"{us_bj / us_bf:.2f}x_vs_jnp_{rate:,.0f}_pkt_per_s"))

    # -- the merge-path sort (ewise_add): 3-argsort pre-pass vs fused
    # variadic 3-key sort over a 2n concat with interleaved validity
    m = 2 * n
    rng2 = np.random.default_rng(1)
    mr = jnp.asarray(rng2.integers(0, 1 << 32, m, dtype=np.uint32))
    mc = jnp.asarray(rng2.integers(0, 1 << 32, m, dtype=np.uint32))
    mv = jnp.asarray(rng2.integers(0, 100, m).astype(np.int32))
    valid = jnp.asarray(rng2.random(m) < 0.5)

    def three_argsort(r, c, v, val):
        perm0 = jnp.argsort(~val, stable=True)
        r, c, v, val = r[perm0], c[perm0], v[perm0], val[perm0]
        perm1 = jnp.argsort(c, stable=True)
        perm2 = jnp.argsort(r[perm1], stable=True)
        perm = perm1[perm2]
        return r[perm], c[perm], v[perm], val[perm]

    def variadic(r, c, v, val):
        from repro.core.build import lex_sort as ls

        return ls(r, c, v, val, valid=val)

    us_m3, _ = _time(jax.jit(three_argsort), mr, mc, mv, valid, iters=iters)
    us_mv, _ = _time(jax.jit(variadic), mr, mc, mv, valid, iters=iters)
    out.append((f"merge_sort_3argsort_2^{n_log2 + 1}", us_m3, "old_merge"))
    out.append((f"merge_sort_variadic_2^{n_log2 + 1}", us_mv,
                f"{us_m3 / us_mv:.2f}x_vs_3argsort"))
    return out


def run(n_log2: int = 17, iters: int = 3):
    from repro.kernels.segsum import ops as segsum_ops
    from repro.kernels.spmm_coo import ops as spmm_ops
    from repro.kernels.spmm_coo.ref import spmm_coo_ref

    rng = np.random.default_rng(0)
    rows = []

    n = 1 << 17
    seg = jnp.asarray(np.sort(rng.integers(0, n // 4, n)).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    us_k, _ = _time(
        lambda v, s: segsum_ops.segment_sum_sorted(v, s, num_segments=n),
        vals, seg,
    )
    us_r, _ = _time(
        lambda v, s: jax.jit(
            lambda v, s: jax.ops.segment_sum(v, s, num_segments=n)
        )(v, s),
        vals, seg,
    )
    rows.append(("segsum_pallas_2^17", us_k, f"xla_ref_{us_r:.0f}us"))

    nr = nc = 4096
    ne = 1 << 16
    er = jnp.asarray(rng.integers(0, nr, ne).astype(np.int32))
    ec = jnp.asarray(rng.integers(0, nc, ne).astype(np.int32))
    ev = jnp.asarray(rng.standard_normal(ne).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((nc, 128)).astype(np.float32))
    us_k, _ = _time(
        lambda r, c, v, xx: spmm_ops.spmm_coo(
            r, c, v, xx, ne, num_rows=nr, strict=False
        ),
        er, ec, ev, x,
    )
    us_r, _ = _time(
        lambda r, c, v, xx: jax.jit(
            lambda r, c, v, xx: spmm_coo_ref(r, c, v, xx, ne, num_rows=nr)
        )(r, c, v, xx),
        er, ec, ev, x,
    )
    rows.append(("spmm_coo_pallas_64k_edges", us_k, f"xla_ref_{us_r:.0f}us"))

    rows.extend(_build_rows(n_log2, iters=iters))
    return rows


def run_json(n_log2: int = 17, iters: int = 3) -> dict:
    """The build-kernel decomposition as a self-describing JSON record."""
    return {
        "suite": "kernels_bench",
        "geometry": {"n_log2": n_log2, "iters": iters},
        "rows": [
            {"name": name, "us": us, "derived": derived}
            for name, us, derived in _build_rows(n_log2, iters=iters)
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small window: fast CI-sized run")
    ap.add_argument("--n-log2", type=int, default=None,
                    help="window size exponent (default 17, the paper's)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json-out", default=None,
                    help="write the record here (default benchmarks/"
                         "results_kernels/kernels_bench[_quick].json)")
    args = ap.parse_args(argv)

    n_log2 = args.n_log2 if args.n_log2 is not None else (
        12 if args.quick else 17
    )
    record = run_json(n_log2=n_log2, iters=args.iters)
    default_name = ("kernels_bench_quick.json" if args.quick
                    else "kernels_bench.json")
    out = (Path(args.json_out) if args.json_out
           else RESULTS_DIR / default_name)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=2) + "\n")

    print("name,us_per_call,derived")
    for r in record["rows"]:
        print(f"{r['name']},{r['us']:.1f},{r['derived']}")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
