"""Pallas-kernel microbenches (interpret mode on CPU — relative numbers;
the BlockSpec tiling is the TPU story, validated structurally)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    out = jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def run():
    from repro.kernels.segsum import ops as segsum_ops
    from repro.kernels.spmm_coo import ops as spmm_ops
    from repro.kernels.spmm_coo.ref import spmm_coo_ref

    rng = np.random.default_rng(0)
    rows = []

    n = 1 << 17
    seg = jnp.asarray(np.sort(rng.integers(0, n // 4, n)).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    us_k, _ = _time(
        lambda v, s: segsum_ops.segment_sum_sorted(v, s, num_segments=n),
        vals, seg,
    )
    us_r, _ = _time(
        lambda v, s: jax.jit(
            lambda v, s: jax.ops.segment_sum(v, s, num_segments=n)
        )(v, s),
        vals, seg,
    )
    rows.append(("segsum_pallas_2^17", us_k, f"xla_ref_{us_r:.0f}us"))

    nr = nc = 4096
    ne = 1 << 16
    er = jnp.asarray(rng.integers(0, nr, ne).astype(np.int32))
    ec = jnp.asarray(rng.integers(0, nc, ne).astype(np.int32))
    ev = jnp.asarray(rng.standard_normal(ne).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((nc, 128)).astype(np.float32))
    us_k, _ = _time(
        lambda r, c, v, xx: spmm_ops.spmm_coo(
            r, c, v, xx, ne, num_rows=nr, strict=False
        ),
        er, ec, ev, x,
    )
    us_r, _ = _time(
        lambda r, c, v, xx: jax.jit(
            lambda r, c, v, xx: spmm_coo_ref(r, c, v, xx, ne, num_rows=nr)
        )(r, c, v, xx),
        er, ec, ev, x,
    )
    rows.append(("spmm_coo_pallas_64k_edges", us_k, f"xla_ref_{us_r:.0f}us"))
    return rows
