"""Paper Fig. 2, GraphBLAS+IO mode: one thread receives packets (host
generation + device transfer = the NIC stand-in), the other builds the
hypersparse matrices — the unified engine's ``double_buffered`` policy
(bounded-queue backpressure), matching the paper's 2-thread pipeline.
Peak there: 8M pkt/s on 8 ARM cores.

``--policy`` swaps the execution policy under the same workload, so the
async-dispatch variants can be compared head to head on one host
(``async_pipelined`` must meet or beat ``double_buffered`` — the overlap
acceptance check).  ``--source`` swaps the producer: the default
``uniform`` host generator is the NIC stand-in (host gen + H2D transfer),
while ``device-uniform``/``device-zipf`` generate on device with zero H2D
copies — the same windows, keyed per global window index, isolating what
the produce path itself costs.  ``--json-out`` records the rows for
``render_experiments.py`` and the acceptance audit.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core.window import WindowConfig
from repro.engine import TrafficEngine, as_source

RESULTS_DIR = Path(__file__).parent / "results_fig2"


def measure(window_log2: int = 17, windows_per_batch: int = 64,
            n_batches: int = 4, thread_pairs=(1, 2, 4),
            anonymization: str = "feistel", policy: str = "double_buffered",
            reps: int = 1, build_kernel: bool = False,
            source: str = "uniform") -> list[dict]:
    """The raw per-row measurements; ``run``/``run_json`` format these."""
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization,
                       build_kernel=build_kernel)
    # Build+merge only in the timed step, like the paper (no analytics).
    engine = TrafficEngine(cfg, policy=policy,
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))

    # default-policy rows keep their historical names so EXPERIMENTS.md
    # renders stay comparable release to release
    tag = "" if policy == "double_buffered" else f"_{policy}"
    if build_kernel:
        tag += "_build_kernel"
    if source != "uniform":
        tag += "_" + str(source).replace("-", "_")
    records = []
    for pairs in thread_pairs:
        # `pairs` producer/consumer pairs: workload scales with pairs; on
        # this 1-core host they serialize (see EXPERIMENTS.md).  ``reps``
        # repeats the row and keeps the best rate — the usual guard
        # against scheduler noise on a shared host.
        best = None
        for _ in range(max(reps, 1)):
            src = as_source(
                source, seed=0, n_batches=pairs * n_batches + 1,
                windows_per_batch=windows_per_batch,
                window_size=cfg.window_size,
            )
            rep = engine.run(src, warmup_items=1, keep_results=False)
            if best is None or rep.packets_per_second > best.packets_per_second:
                best = rep
        records.append({
            "name": f"fig2_graphblas_io{tag}_x{pairs}",
            "us_per_batch": best.elapsed_s / max(best.batches, 1) * 1e6,
            "pkt_per_s": best.packets_per_second,
        })
    return records


def run(**kw):
    """Harness rows (name, us_per_call, derived-CSV cell)."""
    return [
        (r["name"], r["us_per_batch"], f"{r['pkt_per_s']:,.0f}_pkt_per_s")
        for r in measure(**kw)
    ]


def run_json(policy: str, **kw) -> dict:
    """One policy's curve as a self-describing JSON record (the geometry
    rides along so readers can tell a quick run from a recorded sweep)."""
    return {
        "suite": "fig2_graphblas_io",
        "policy": policy,
        "source": kw.get("source", "uniform"),
        "build_kernel": kw.get("build_kernel", False),
        "geometry": {
            "window_log2": kw.get("window_log2", 17),
            "windows_per_batch": kw.get("windows_per_batch", 64),
            "n_batches": kw.get("n_batches", 4),
            "reps": kw.get("reps", 1),
        },
        "rows": measure(policy=policy, **kw),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="double_buffered",
                    help="any registered engine policy, e.g. "
                         "double_buffered | async_pipelined")
    ap.add_argument("--quick", action="store_true",
                    help="small windows: fast CI-sized run")
    ap.add_argument("--window-log2", type=int, default=None)
    ap.add_argument("--windows-per-batch", type=int, default=None)
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--reps", type=int, default=1,
                    help="repeat each row, keep the best rate "
                         "(noise guard on shared hosts)")
    ap.add_argument("--build-kernel", action="store_true",
                    help="route builds through the fused Pallas kernel "
                         "(kernels/build_fused)")
    ap.add_argument("--source", default="uniform",
                    help="source spec: uniform (host gen + H2D, the NIC "
                         "stand-in) | zipf | device-uniform | device-zipf "
                         "(device-resident, zero H2D)")
    ap.add_argument("--json-out", default=None,
                    help="write the record here (default "
                         "benchmarks/results_fig2/fig2_graphblas_io_"
                         "<policy>.json)")
    args = ap.parse_args(argv)

    kw = (dict(window_log2=12, windows_per_batch=8, n_batches=2,
               thread_pairs=(1, 2)) if args.quick else {})
    if args.window_log2 is not None:
        kw["window_log2"] = args.window_log2
    if args.windows_per_batch is not None:
        kw["windows_per_batch"] = args.windows_per_batch
    if args.batches is not None:
        kw["n_batches"] = args.batches
    kw["reps"] = args.reps
    kw["build_kernel"] = args.build_kernel
    kw["source"] = args.source
    record = run_json(args.policy, **kw)
    # --quick defaults to a _quick artifact so a CI-sized run never
    # clobbers a recorded sweep; an explicit --json-out always wins
    ktag = "_build_kernel" if args.build_kernel else ""
    if args.source != "uniform":
        ktag += "_" + args.source.replace("-", "_")
    default_name = (f"fig2_graphblas_io_{args.policy}{ktag}_quick.json"
                    if args.quick else
                    f"fig2_graphblas_io_{args.policy}{ktag}.json")
    out = (Path(args.json_out) if args.json_out
           else RESULTS_DIR / default_name)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=2) + "\n")

    print("name,us_per_call,derived")
    for r in record["rows"]:
        print(f"{r['name']},{r['us_per_batch']:.1f},"
              f"{r['pkt_per_s']:,.0f}_pkt_per_s")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
