"""Paper Fig. 2, GraphBLAS+IO mode: one thread receives packets (host
generation + device transfer = the NIC stand-in), the other builds the
hypersparse matrices — the unified engine's ``double_buffered`` policy
(bounded-queue backpressure), matching the paper's 2-thread pipeline.
Peak there: 8M pkt/s on 8 ARM cores.
"""

from __future__ import annotations

from repro.core.window import WindowConfig
from repro.engine import SyntheticSource, TrafficEngine


def run(window_log2: int = 17, windows_per_batch: int = 64,
        n_batches: int = 4, thread_pairs=(1, 2, 4),
        anonymization: str = "feistel"):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)
    # Build+merge only in the timed step, like the paper (no analytics).
    engine = TrafficEngine(cfg, policy="double_buffered",
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))

    rows = []
    for pairs in thread_pairs:
        # `pairs` producer/consumer pairs: workload scales with pairs; on
        # this 1-core host they serialize (see EXPERIMENTS.md)
        src = SyntheticSource(
            seed=0, n_batches=pairs * n_batches + 1,
            windows_per_batch=windows_per_batch,
            window_size=cfg.window_size,
        )
        rep = engine.run(src, warmup_items=1)
        rows.append((
            f"fig2_graphblas_io_x{pairs}",
            rep.elapsed_s / max(rep.batches, 1) * 1e6,
            f"{rep.packets_per_second:,.0f}_pkt_per_s",
        ))
    return rows
