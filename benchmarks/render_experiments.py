"""Fill EXPERIMENTS.md placeholders from measured artifacts:
<!-- FIG2_RESULTS -->, <!-- ROOFLINE_TABLE -->, <!-- PERF_LOG -->.

Run after the dry-run sweep, perf iterations, and benchmarks.run.
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).parent.parent
RESULTS_PERF = Path(__file__).parent / "results_perf"


def fig2_section() -> str:
    """Parse fig2 rows out of bench_output.txt."""
    path = ROOT / "bench_output.txt"
    if not path.exists():
        return "*(run `python -m benchmarks.run` to populate)*"
    rows = []
    for line in path.read_text().splitlines():
        if line.startswith(("fig2_", "window_size_")):
            name, us, derived = line.split(",", 2)
            rate = derived.replace("_pkt_per_s", "").strip()
            rows.append((name, float(us), rate))
    if not rows:
        return "*(no fig2 rows in bench_output.txt)*"
    out = ["| mode | us/window | packets/s |", "|---|---|---|"]
    for name, us, rate in rows:
        out.append(f"| {name} | {us:,.0f} | {rate} |")
    return "\n".join(out)


def depth_sweep_section() -> str:
    """Render the depth-vs-pkt/s sweep (benchmarks.depth_sweep JSON)."""
    path = Path(__file__).parent / "results_depth" / "depth_sweep.json"
    if not path.exists():
        return "*(run `python -m benchmarks.depth_sweep` to populate)*"
    rec = json.loads(path.read_text())
    out = ["| policy | depth | us/batch | packets/s | exposed wait s | "
           "overlap s |", "|---|---|---|---|---|---|"]
    for r in rec.get("rows", []):
        out.append(
            f"| {r['policy']} | {r['depth']} | {r['us_per_batch']:,.0f} | "
            f"{r['pkt_per_s']:,.0f} | {r['process_s']:.3f} | "
            f"{r['overlap_s']:.3f} |"
        )
    return "\n".join(out)


def roofline_section() -> str:
    from benchmarks import roofline

    recs = roofline.load_records()
    return roofline.fmt_table(recs, only_ok=False)


def _fmt_rec(r) -> str:
    if r.get("status") != "ok":
        return f"ERROR {r.get('error', '')[:80]}"
    rf = r["roofline"]
    mem = r.get("memory_per_device", {}).get("total_bytes", 0) / 1e9
    return (f"compute {rf['compute_s']:.3f}s, memory {rf['memory_s']:.3f}s, "
            f"collective {rf['collective_s']:.3f}s, mem/dev {mem:.1f}GB, "
            f"dominant {rf['dominant']}")


PERF_NARRATIVE = {
    "qwen2-moe-a2.7b__prefill_32k": [
        ("hypothesis v1",
         "the 77.6GB/device comes from XLA replicating the global-sort "
         "dispatch gather [T*k, d] per device (napkin: 1M tokens x top4 x "
         "2048 x bf16 = 17GB, several live copies through fwd) AND "
         "re-running the expert GEMMs redundantly per shard; dispatching "
         "per-shard in shard_map (x is model-replicated under Megatron TP, "
         "so routing is shard-local and communication-FREE; combine = one "
         "psum[t_loc, d]) should cut memory ~8x and compute ~TPx"),
        ("hypothesis v2",
         "bf16 attention scores should further cut bytes — REFUTED: the "
         "extra convert ops around the f32 softmax ADD unfused "
         "bytes-accessed in the cost model (memory 3.22s -> 3.46s)"),
        ("lesson",
         "auto-sharding cannot infer that data-dependent sort/gather "
         "pipelines are shard-local; the sort-based dispatch (the paper's "
         "build primitive) must be explicitly placed with shard_map"),
    ],
    "phi3.5-moe-42b-a6.6b__train_4k": [
        ("hypothesis v1",
         "the 124s collective term is the same dispatch pathology at "
         "training scale; EP shard_map should collapse it to one psum of "
         "[t_loc, d] per layer (napkin: 8192 x 4096 x 4B x 2 x 32L x "
         "8micro x fwd+bwd / 50GB/s ~ a few s) — CONFIRMED beyond the "
         "napkin: 124.1s -> 0.89s collective, 92.2s -> 11.3s memory, "
         "17.2s -> 0.84s compute (the baseline redundantly computed "
         "expert GEMMs per shard)"),
        ("hypothesis v2",
         "replicate_kv + bf16 scores on top of EP — REFUTED for training: "
         "replicated K/V weights need gradient all-reduces over `model` "
         "larger than the activation resharding they remove (collective "
         "0.89s -> 1.94s)"),
    ],
    "granite-3-8b__train_4k": [
        ("hypothesis v1",
         "GQA K/V projections (kv8 < TP16) force a (8,2) head/dim split "
         "whose resharding SPMD solves by involuntary full "
         "rematerialization; replicating the small K/V weights should "
         "remove those collectives — REFUTED for training: K/V weight "
         "GRADIENTS then all-reduce over `model` (40L x 2 x 4096x1024 f32 "
         "per micro), collective 3.52s -> 4.59s"),
        ("hypothesis v2/v3",
         "dots-saveable remat cuts recompute (compute 1.63s -> 1.42s, "
         "CONFIRMED) but saves [*, s, s]-scale dots: mem/dev 24 -> 55GB, "
         "REFUTED as a net win at this batch"),
        ("hypothesis v4/v5",
         "sequence-parallel residual constraints shard norm/residual "
         "bytes by 16 — memory/device 24.0 -> 15.7GB (fits v5e, "
         "CONFIRMED) but constraint-based SP lets XLA thrash reshards "
         "(collective 3.5s -> 20.1s, REFUTED as placed); proper Megatron "
         "SP needs manual RS/AG in shard_map — recorded as the next "
         "iteration. bf16 grad-reduce cast was absorbed by XLA before "
         "the reduce (no delta, REFUTED as implemented)"),
        ("net",
         "baseline remains the best total for train_4k; the GQA fix that "
         "sticks is for inference (see prefill note) and the memory fix "
         "is SP-with-manual-collectives"),
    ],
    "granite-3-8b__prefill_32k": [
        ("hypothesis v6",
         "replicate_kv helps PREFILL (no weight gradients): memory "
         "9.28s -> 9.20s, mem/dev 6.2 -> 7.7GB, but collective "
         "2.56s -> 4.83s — REFUTED: the k/v activations themselves "
         "(32k seq, replicated) now reshard into the seq-sharded cache "
         "layout; GQA at TP>kv_heads wants TP<=kv_heads for the KV path, "
         "i.e. a (kv=8)-way subgroup — mesh-reshape iteration left in "
         "the backlog"),
    ],
    "traffic-matrix__ingest_512w": [
        ("hypothesis v1 (exact merge)",
         "baseline distributed analytics psums device-local stats "
         "(distinct counts = upper bound); routing entries to row-block "
         "owners via all_to_all (2D decomposition of the 2^32 space) "
         "makes distinct-source/link counts EXACT for ~3MB/device of "
         "all_to_all traffic. CONFIRMED exact (test vs direct build) at "
         "47x the (microscopic) baseline memory term: 49us -> 2.3ms per "
         "67M-packet step — 512-chip step lower bound still ~29 Gpkt/s"),
        ("hypothesis v2 (count-build)",
         "counting builds don't need a value payload: run lengths fall "
         "out of run-head positions, dropping one [2^17] gather + the "
         "segment reduction from the build hot loop; expect ~10-20% off "
         "the memory term of the build stage"),
    ],
    "pna__ogb_products": [
        ("hypothesis v1",
         "the 86GB/device comes from REPLICATED 2.45M-node activations "
         "(4 aggregators x 3 scalers x d75 f32 intermediates); sharding "
         "node arrays over `data` divides those bytes by 16 at the cost "
         "of all-gathers for the edge-wise gathers h[src]"),
    ],
}


def perf_section() -> str:
    if not RESULTS_PERF.exists():
        return "*(run `python -m benchmarks.perf_iterations`)*"
    base = {}
    for p in (Path(__file__).parent / "results").glob("*.json"):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            base[(r["arch"], r["shape"], r["mesh"])] = r
    groups: dict = {}
    for p in sorted(RESULTS_PERF.glob("*.json")):
        r = json.loads(p.read_text())
        groups.setdefault((r["arch"], r["shape"], r["mesh"]), []).append(r)

    out = []
    for (arch, shape, mesh), variants in groups.items():
        out.append(f"### {arch} × {shape} [{mesh}]\n")
        for label, text in PERF_NARRATIVE.get(f"{arch}__{shape}", []):
            out.append(f"*{label}*: {text}\n")
        b = base.get((arch, shape, mesh))
        if b:
            out.append(f"- **baseline (paper-faithful)**: {_fmt_rec(b)}")
        for v in variants:
            out.append(f"- **{v.get('variant')}**: {_fmt_rec(v)}")
        # verdicts
        if b and variants:
            ok_vs = [v for v in variants if v.get("status") == "ok"]
            if ok_vs:
                best = min(
                    ok_vs,
                    key=lambda v: v["roofline"]["step_s_lower_bound"],
                )
                b0 = b["roofline"]["step_s_lower_bound"]
                b1 = best["roofline"]["step_s_lower_bound"]
                if b1 < b0:
                    out.append(
                        f"- **verdict**: {best['variant']} CONFIRMED — "
                        f"step lower bound {b0:.3f}s -> {b1:.3f}s "
                        f"({b0/b1:.1f}x)"
                    )
                else:
                    out.append(
                        "- **verdict**: no variant beat the baseline "
                        "lower bound — hypotheses REFUTED (see notes)"
                    )
        out.append("")
    return "\n".join(out)


def main():
    path = ROOT / "EXPERIMENTS.md"
    if not path.exists():
        print("EXPERIMENTS.md not found; nothing to render")
        return
    text = path.read_text()
    text = text.replace("<!-- FIG2_RESULTS -->", fig2_section())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_section())
    text = text.replace("<!-- PERF_LOG -->", perf_section())
    text = text.replace("<!-- DEPTH_SWEEP -->", depth_sweep_section())
    path.write_text(text)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
