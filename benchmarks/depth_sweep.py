"""Queue-depth vs pkt/s sweep: how deep should the pipeline be?

The ROADMAP's open question after the ``triple_buffered`` preset landed:
sweep in-flight depth {1, 2, 4, 8} across the pipelined policies —

* ``double_buffered``   — depth = producer queue depth (host IO overlap
  only; the device loop still blocks per batch);
* ``async_pipelined``   — depth = both the producer queue and the ring of
  async-dispatched batches (IO *and* readback overlap);
* ``sharded_pipelined`` — the same ring in front of the mesh-parallel
  exact-merge step.  Its in-family serialization baseline is the blocking
  ``sharded`` policy, recorded alongside as the ``sharded`` row (the
  shard_map step does more work per batch than the single-device graph, so
  comparing it against ``double_buffered`` across families measures the
  mesh overhead, not the pipelining).

Depth 1 is the degenerate "no lookahead" point for each policy, so each
curve's own depth-1 row is its serialization baseline.

The default source is ``device-uniform`` (device-resident ``jax.random``
generation, zero H2D copies on the produce path) so the sweep measures the
dispatch discipline rather than host generator throughput.  Each run
drives real sinks (stats + retained-matrix readback for the graph-path
policies — see ``sinks_for``) because lookahead only matters when the
host does per-batch work the ring can hide device time behind; a sinkless
sweep measures pure ``block_until_ready`` and reads ~zero overlap for
every policy.  Measurements are best-of-``reps`` with the reps
*interleaved* round-robin across rows:
a transient load spike on a shared host then degrades one rep of every
row instead of every rep of one row, which keeps within-file comparisons
honest.  Rows print in the harness CSV format; ``run(json_path=...)``
(and the CLI) also record a JSON artifact that ``render_experiments.py``'s
depth-sweep section renders into EXPERIMENTS.md.

``--check`` is the CI smoke: a small-geometry run asserting that
``async_pipelined`` still overlaps (``overlap_s > 0``) and that depth 2
does not lose throughput vs depth 1 (best-of-reps, small tolerance for
runner noise).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core.window import WindowConfig
from repro.engine import (
    MatrixRetention,
    StatsAccumulator,
    TrafficEngine,
    make_policy,
)

DEPTHS = (1, 2, 4, 8)
POLICIES = ("double_buffered", "async_pipelined", "sharded_pipelined")
DEFAULT_SOURCE = "device-uniform"
DEFAULT_JSON = Path(__file__).parent / "results_depth" / "depth_sweep.json"

# Full-sweep geometry: 1024-packet windows x 8 per batch, enough batches
# that steady state dominates warmup.  Chosen so per-batch host work (sink
# readback + dispatch) is a visible fraction of per-batch device compute —
# that is the regime where lookahead has something to hide, so overlap_s
# is measurable rather than epsilon.
FULL = dict(window_log2=10, windows_per_batch=8, n_batches=64)


def sinks_for(policy_name: str) -> list:
    """The sweep's per-batch host work: stats accumulation + retained-
    matrix readback (the paper pipeline's "IO" half).  The sharded family
    runs stats-only — its mesh step exposes just stats/overflow — so its
    rows compare within the family (``sharded`` baseline vs
    ``sharded_pipelined``), not against the graph-path policies."""
    if policy_name in ("sharded", "sharded_pipelined"):
        return [StatsAccumulator()]
    return [StatsAccumulator(), MatrixRetention(max_keep=2)]


def policy_at_depth(name: str, depth: int, *, producer_workers: int = 1,
                    submit_batches: int = 1):
    """Instantiate ``name`` with ``depth`` applied to its lookahead knob.

    ``producer_workers``/``submit_batches`` forward to the policies that
    take them (``make_policy`` drops None and rejects unsupported knobs).
    """
    extra = dict(producer_workers=producer_workers)
    if name == "double_buffered":
        return make_policy(name, queue_depth=depth, **extra)
    if name == "async_pipelined" or name == "sharded_pipelined":
        return make_policy(name, max_in_flight=depth, queue_depth=depth,
                           submit_batches=submit_batches, **extra)
    if name == "sharded":
        # the blocking baseline has no lookahead knob at all
        return make_policy(name)
    raise ValueError(f"no depth knob defined for policy {name!r}")


def run(window_log2: int = FULL["window_log2"],
        windows_per_batch: int = FULL["windows_per_batch"],
        n_batches: int = FULL["n_batches"], depths=DEPTHS,
        policies=POLICIES, anonymization: str = "feistel",
        source: str = DEFAULT_SOURCE, reps: int = 1,
        producer_workers: int = 1, submit_batches: int = 1,
        json_path=DEFAULT_JSON):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)

    # One (engine, knob-set) per row, built up front so every rep of a row
    # reuses the row's compiled stage graph, then reps interleaved
    # round-robin across rows (see module docstring).
    configs: list[tuple[str, int, TrafficEngine]] = []
    for name in policies:
        for depth in depths:
            pol = policy_at_depth(name, depth,
                                  producer_workers=producer_workers,
                                  submit_batches=submit_batches)
            configs.append((name, depth, TrafficEngine(
                cfg, policy=pol, sinks=sinks_for(name))))
    if "sharded_pipelined" in policies and "sharded" not in policies:
        configs.append(("sharded", 1, TrafficEngine(
            cfg, policy=policy_at_depth("sharded", 1),
            sinks=sinks_for("sharded"))))

    best: dict[int, object] = {}
    for _ in range(max(1, reps)):
        for i, (_, _, engine) in enumerate(configs):
            rep = engine.run(source, n_batches=n_batches + 1, seed=0,
                             warmup_items=1, keep_results=False)
            if (i not in best
                    or rep.packets_per_second
                    > best[i].packets_per_second):
                best[i] = rep

    rows, records = [], []
    for i, (name, depth, _) in enumerate(configs):
        rep = best[i]
        rows.append((
            f"depth_sweep_{name}_d{depth}",
            rep.elapsed_s / max(rep.batches, 1) * 1e6,
            f"{rep.packets_per_second:,.0f}_pkt_per_s",
        ))
        records.append({
            "policy": name,
            "sinks": [s.name for s in sinks_for(name)],
            "depth": depth,
            "us_per_batch": rep.elapsed_s / max(rep.batches, 1) * 1e6,
            "pkt_per_s": rep.packets_per_second,
            "elapsed_s": rep.elapsed_s,
            "produce_s": rep.produce_s,
            "process_s": rep.process_s,
            "overlap_s": rep.overlap_s,
            "overlap_frac": (rep.overlap_s / rep.elapsed_s
                             if rep.elapsed_s > 0 else 0.0),
            "max_in_flight": rep.max_in_flight,
            "producer_workers": rep.producer_workers,
            "submit_batches": rep.submit_batches,
        })
    if json_path is not None:
        json_path = Path(json_path)
        json_path.parent.mkdir(parents=True, exist_ok=True)
        json_path.write_text(json.dumps({
            "suite": "depth_sweep",
            "source": source,
            "window_log2": window_log2,
            "windows_per_batch": windows_per_batch,
            "n_batches": n_batches,
            "reps": reps,
            "producer_workers": producer_workers,
            "submit_batches": submit_batches,
            "rows": records,
        }, indent=2) + "\n")
    return rows


# CI smoke geometry: small enough for a shared runner, large enough that
# the async ring's depth-1 exposed wait is measurable.
CHECK = dict(window_log2=11, windows_per_batch=4, n_batches=24)
# best-of-reps tolerance for depth 2 >= depth 1: absorbs runner noise
# without masking a real regression (a broken ring loses far more than 5%)
CHECK_TOL = 0.95


def check(reps: int = 3, source: str = DEFAULT_SOURCE) -> int:
    """CI smoke: async_pipelined must still overlap, and lookahead must
    not cost throughput.  Asserts, on a best-of-``reps`` interleaved run:

    * ``overlap_s > 0`` at depth 2 — the ring actually hides in-flight
      batches behind host work (the tentpole claim, as a cheap invariant);
    * depth-2 throughput >= ``CHECK_TOL`` x depth-1 throughput — lookahead
      never *loses* pkt/s (depth 1 serializes submit->retire, so a working
      ring is at worst equal).
    """
    cfg = WindowConfig(window_log2=CHECK["window_log2"],
                       windows_per_batch=CHECK["windows_per_batch"],
                       anonymization="feistel")
    engines = {
        d: TrafficEngine(cfg, policy=policy_at_depth("async_pipelined", d))
        for d in (1, 2)
    }
    best = {}
    for _ in range(max(1, reps)):
        for d, engine in engines.items():
            rep = engine.run(source, n_batches=CHECK["n_batches"] + 1,
                             seed=0, warmup_items=1, keep_results=False)
            if d not in best or rep.packets_per_second > \
                    best[d].packets_per_second:
                best[d] = rep
    r1, r2 = best[1], best[2]
    print(f"depth_sweep --check: d1 {r1.packets_per_second:,.0f} pkt/s | "
          f"d2 {r2.packets_per_second:,.0f} pkt/s, "
          f"overlap {r2.overlap_s:.3f}s/{r2.elapsed_s:.3f}s")
    ok = True
    if not r2.overlap_s > 0:
        print("FAIL: async_pipelined depth=2 recorded no overlap_s")
        ok = False
    if r2.packets_per_second < CHECK_TOL * r1.packets_per_second:
        print(f"FAIL: depth 2 throughput {r2.packets_per_second:,.0f} < "
              f"{CHECK_TOL} x depth-1 {r1.packets_per_second:,.0f}")
        ok = False
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small windows + depths (1, 2, 4): CI-sized run")
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: assert async_pipelined overlap_s > 0 "
                         "and non-decreasing throughput depth 1 -> 2")
    ap.add_argument("--source", default=DEFAULT_SOURCE,
                    help="source spec (default device-uniform; also "
                         "uniform, zipf, device-zipf, ...)")
    ap.add_argument("--reps", type=int, default=None,
                    help="best-of-N, reps interleaved across rows "
                         "(default: 5 full, 1 quick, 3 check)")
    ap.add_argument("--producer-workers", type=int, default=1)
    ap.add_argument("--submit-batches", type=int, default=1)
    ap.add_argument("--json-out", default=None,
                    help="default benchmarks/results_depth/depth_sweep"
                         ".json (quick runs go to ..._quick.json so they "
                         "never clobber a recorded full sweep)")
    args = ap.parse_args(argv)
    if args.check:
        return check(reps=args.reps or 3, source=args.source)
    if args.json_out is None:
        args.json_out = str(
            DEFAULT_JSON.with_name("depth_sweep_quick.json")
            if args.quick else DEFAULT_JSON
        )
    kw = (dict(window_log2=10, windows_per_batch=4, n_batches=4,
               depths=(1, 2, 4)) if args.quick else {})
    kw.update(source=args.source,
              reps=args.reps or (1 if args.quick else 5),
              producer_workers=args.producer_workers,
              submit_batches=args.submit_batches)
    print("name,us_per_call,derived")
    for name, us, derived in run(json_path=args.json_out, **kw):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
