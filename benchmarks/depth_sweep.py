"""Queue-depth vs pkt/s sweep: how deep should the pipeline be?

The ROADMAP's open question after the ``triple_buffered`` preset landed:
sweep in-flight depth {1, 2, 3, 4, 8} across the pipelined policies —

* ``double_buffered``   — depth = producer queue depth (host IO overlap
  only; the device loop still blocks per batch);
* ``async_pipelined``   — depth = both the producer queue and the ring of
  async-dispatched batches (IO *and* readback overlap);
* ``sharded_pipelined`` — the same ring in front of the mesh-parallel
  exact-merge step.

Depth 1 is the degenerate "no lookahead" point for each policy, so each
curve's own depth-1 row is its serialization baseline.  Rows print in the
harness CSV format; ``run(json_path=...)`` (and the CLI) also record a
JSON artifact that ``render_experiments.py``'s depth-sweep section renders
into EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core.window import WindowConfig
from repro.engine import (
    AsyncPipelinedPolicy,
    DoubleBufferedPolicy,
    ShardedPipelinedPolicy,
    TrafficEngine,
)

DEPTHS = (1, 2, 3, 4, 8)
POLICIES = ("double_buffered", "async_pipelined", "sharded_pipelined")
DEFAULT_JSON = Path(__file__).parent / "results_depth" / "depth_sweep.json"


def policy_at_depth(name: str, depth: int):
    """Instantiate ``name`` with ``depth`` applied to its lookahead knob."""
    if name == "double_buffered":
        return DoubleBufferedPolicy(queue_depth=depth)
    if name == "async_pipelined":
        return AsyncPipelinedPolicy(max_in_flight=depth, queue_depth=depth)
    if name == "sharded_pipelined":
        return ShardedPipelinedPolicy(max_in_flight=depth,
                                      queue_depth=depth)
    raise ValueError(f"no depth knob defined for policy {name!r}")


def run(window_log2: int = 15, windows_per_batch: int = 8,
        n_batches: int = 4, depths=DEPTHS, policies=POLICIES,
        anonymization: str = "feistel", json_path=DEFAULT_JSON):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)
    rows, records = [], []
    for name in policies:
        for depth in depths:
            engine = TrafficEngine(cfg, policy=policy_at_depth(name, depth))
            rep = engine.run("uniform", n_batches=n_batches + 1, seed=0,
                             warmup_items=1, keep_results=False)
            rows.append((
                f"depth_sweep_{name}_d{depth}",
                rep.elapsed_s / max(rep.batches, 1) * 1e6,
                f"{rep.packets_per_second:,.0f}_pkt_per_s",
            ))
            records.append({
                "policy": name,
                "depth": depth,
                "us_per_batch": rep.elapsed_s / max(rep.batches, 1) * 1e6,
                "pkt_per_s": rep.packets_per_second,
                "process_s": rep.process_s,
                "overlap_s": rep.overlap_s,
                "max_in_flight": rep.max_in_flight,
            })
    if json_path is not None:
        json_path = Path(json_path)
        json_path.parent.mkdir(parents=True, exist_ok=True)
        json_path.write_text(json.dumps({
            "suite": "depth_sweep",
            "window_log2": window_log2,
            "windows_per_batch": windows_per_batch,
            "n_batches": n_batches,
            "rows": records,
        }, indent=2) + "\n")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small windows + depths (1, 2, 4): CI-sized run")
    ap.add_argument("--json-out", default=None,
                    help="default benchmarks/results_depth/depth_sweep"
                         ".json (quick runs go to ..._quick.json so they "
                         "never clobber a recorded full sweep)")
    args = ap.parse_args(argv)
    if args.json_out is None:
        args.json_out = str(
            DEFAULT_JSON.with_name("depth_sweep_quick.json")
            if args.quick else DEFAULT_JSON
        )
    kw = (dict(window_log2=12, windows_per_batch=4, n_batches=2,
               depths=(1, 2, 4)) if args.quick else {})
    print("name,us_per_call,derived")
    for name, us, derived in run(json_path=args.json_out, **kw):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
