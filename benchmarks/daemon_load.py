"""Daemon load test: sustained ingest pkt/s + query latency under load.

Runs an ``AnalyticsDaemon`` in-process (TCP on an ephemeral port), drives
it with one socket ingest client streaming synthetic batches, and — while
ingest is in flight — hammers the roll-up query API from N concurrent
query clients.  Rows (harness CSV format):

  ``daemon_load_ingest``        — wall-per-batch over the socket ingest
                                  path; derived carries sustained pkt/s
  ``daemon_load_query_cN``      — per-query latency with N concurrent
                                  query clients (mixed status/top_links/
                                  top_talkers/fanout workload), derived
                                  carries p50/p95 and queries/s

``--quick`` keeps geometry CI-sized; the CI ``daemon`` job runs it as the
short-burst driver in front of the SIGTERM shutdown check.
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.window import WindowConfig
from repro.engine.source import DeviceSyntheticSource
from repro.serve.client import DaemonClient, IngestClient
from repro.serve.daemon import AnalyticsDaemon

FULL = dict(window_log2=12, windows_per_batch=16, n_batches=48)
QUICK = dict(window_log2=8, windows_per_batch=4, n_batches=12)


def _batches(cfg: WindowConfig, n_batches: int) -> list[np.ndarray]:
    return list(DeviceSyntheticSource(
        kind="uniform", seed=0, n_batches=n_batches,
        windows_per_batch=cfg.windows_per_batch,
        window_size=cfg.window_size, placement="host",
    ))


def _query_worker(address: str, stop: threading.Event,
                  latencies: list, lock: threading.Lock) -> None:
    kinds = ("status", "top_links", "top_talkers", "fanout")
    local: list[float] = []
    with DaemonClient(address) as client:
        i = 0
        while not stop.is_set():
            kind = kinds[i % len(kinds)]
            t0 = time.perf_counter()
            if kind == "status":
                client.query(kind)
            else:
                client.query(kind, level=0, index=-1)
            local.append(time.perf_counter() - t0)
            i += 1
    with lock:
        latencies.extend(local)


def run(window_log2: int, windows_per_batch: int, n_batches: int,
        clients: tuple[int, ...] = (1, 4, 8)):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization="feistel")
    batches = _batches(cfg, n_batches)
    rows = []

    # -- ingest throughput (no query load) ----------------------------------
    daemon = AnalyticsDaemon(cfg, policy="blocking", rollup_levels=3,
                             queue_depth=8)
    address = daemon.bind("tcp://127.0.0.1:0")
    daemon.start()
    with IngestClient(address) as ingest, DaemonClient(address) as ctl:
        ingest.send_batch(batches[0])  # absorb jit compile
        ctl.wait_consumed(1, timeout=120.0)
        t0 = time.perf_counter()
        ingest.send_stream(batches[1:])
        ingest.end()
        ctl.wait_consumed(len(batches), timeout=120.0)
        ingest_s = time.perf_counter() - t0
        ctl.shutdown()
    report = daemon.join()
    daemon.finalize()
    measured = len(batches) - 1
    pkts = measured * cfg.window_size * cfg.windows_per_batch
    rows.append((
        "daemon_load_ingest",
        ingest_s / max(measured, 1) * 1e6,
        f"{pkts / ingest_s:,.0f}_pkt_per_s_{report.batches}_batches",
    ))

    # -- query latency under N concurrent clients ---------------------------
    for n_clients in clients:
        daemon = AnalyticsDaemon(cfg, policy="blocking", rollup_levels=3,
                                 queue_depth=8)
        address = daemon.bind("tcp://127.0.0.1:0")
        daemon.start()
        with IngestClient(address) as ingest, DaemonClient(address) as ctl:
            # seed the hierarchy so queries have aggregates to read
            warm = min(4, len(batches))
            ingest.send_stream(batches[:warm])
            ctl.wait_consumed(warm, timeout=120.0)

            stop = threading.Event()
            latencies: list[float] = []
            lock = threading.Lock()
            workers = [
                threading.Thread(target=_query_worker,
                                 args=(address, stop, latencies, lock))
                for _ in range(n_clients)
            ]
            for w in workers:
                w.start()
            t0 = time.perf_counter()
            ingest.send_stream(batches[warm:])
            ingest.end()
            ctl.wait_consumed(len(batches), timeout=120.0)
            # keep querying ~0.2s past drain for a stable sample
            time.sleep(0.2)
            stop.set()
            for w in workers:
                w.join()
            span = time.perf_counter() - t0
            ctl.shutdown()
        daemon.join()
        daemon.finalize()
        lat = np.sort(np.asarray(latencies)) * 1e6
        p50 = float(lat[len(lat) // 2]) if len(lat) else 0.0
        p95 = float(lat[int(len(lat) * 0.95)]) if len(lat) else 0.0
        qps = len(lat) / span if span > 0 else 0.0
        rows.append((
            f"daemon_load_query_c{n_clients}",
            float(lat.mean()) if len(lat) else 0.0,
            f"p50_{p50:.0f}us_p95_{p95:.0f}us_{qps:,.0f}_q_per_s",
        ))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized geometry + fewer client counts")
    args = ap.parse_args(argv)
    geom = QUICK if args.quick else FULL
    clients = (1, 4) if args.quick else (1, 4, 8)
    rows = run(geom["window_log2"], geom["windows_per_batch"],
               geom["n_batches"], clients=clients)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
