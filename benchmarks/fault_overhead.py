"""Fault-tolerance overhead: what does robustness cost per batch?

The PR-9 runtime adds three optional layers to the ingest engine —

* the fault-injection / retry / quarantine source wrappers
  (``engine.faults.FaultTolerance``),
* per-batch crash-consistent checkpoints (``checkpoint_every=k`` through a
  ``CheckpointManager``),
* the retry path actually firing (transient source faults that succeed on
  re-attempt).

Each is free when unused; this suite measures what it costs when used, in
the harness CSV format, against the same baseline engine run.  Rows:

  ``fault_overhead_baseline``     — plain run, no wrappers, no checkpoints
  ``fault_overhead_ft_wrapped``   — FaultTolerance wrapping with an *empty*
                                    fault plan (the pure wrapper tax:
                                    cursor accounting + validator off)
  ``fault_overhead_ckpt_every2``  — checkpoint after every 2nd batch
  ``fault_overhead_ckpt_every1``  — checkpoint after every batch (the
                                    resume-granularity worst case)
  ``fault_overhead_transients``   — one transient fault per 4 batches,
                                    each retried successfully

``derived`` carries pkt/s plus the overhead vs the baseline row, so the
CSV reads as a cost table without post-processing.  Checkpoints go to a
throwaway temp directory that is removed afterwards.
"""

from __future__ import annotations

import argparse
import shutil
import tempfile

from repro.core.window import WindowConfig
from repro.engine import (
    MatrixRetention,
    StatsAccumulator,
    TrafficEngine,
)
from repro.engine.faults import FaultPlan, FaultTolerance

FULL = dict(window_log2=10, windows_per_batch=8, n_batches=32)
SOURCE = "device-uniform"


def _engine(cfg: WindowConfig) -> TrafficEngine:
    return TrafficEngine(cfg, policy="blocking",
                         sinks=[StatsAccumulator(),
                                MatrixRetention(max_keep=2)])


def _transient_plan(n_batches: int) -> FaultPlan:
    """One transient read fault every 4th measured batch (stream index is
    warmup-inclusive, so measured batch k is stream batch k+1)."""
    spec = ",".join(f"transient:1@{b}" for b in range(1, n_batches + 1, 4))
    return FaultPlan.parse(spec)


def run(window_log2: int = FULL["window_log2"],
        windows_per_batch: int = FULL["windows_per_batch"],
        n_batches: int = FULL["n_batches"], reps: int = 1):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization="feistel")
    ckpt_dir = tempfile.mkdtemp(prefix="repro-fault-overhead-")
    try:
        from repro.checkpoint.manager import CheckpointManager

        # variant -> run kwargs; engines built up front so every rep of a
        # row reuses its compiled stage graph (depth_sweep discipline)
        variants: list[tuple[str, TrafficEngine, dict]] = [
            ("baseline", _engine(cfg), {}),
            ("ft_wrapped", _engine(cfg),
             dict(fault_tolerance=FaultTolerance(plan=FaultPlan()))),
            ("ckpt_every2", _engine(cfg),
             dict(checkpoint_every=2,
                  checkpoint_manager=CheckpointManager(ckpt_dir))),
            ("ckpt_every1", _engine(cfg),
             dict(checkpoint_every=1,
                  checkpoint_manager=CheckpointManager(ckpt_dir))),
            ("transients", _engine(cfg),
             dict(fault_tolerance=FaultTolerance(
                 plan=_transient_plan(n_batches), max_retries=3))),
        ]

        best: dict[int, object] = {}
        for _ in range(max(1, reps)):
            for i, (_, engine, kw) in enumerate(variants):
                rep = engine.run(SOURCE, n_batches=n_batches + 1, seed=0,
                                 warmup_items=1, keep_results=False, **kw)
                if (i not in best
                        or rep.packets_per_second
                        > best[i].packets_per_second):
                    best[i] = rep
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    base = best[0]
    base_us = base.elapsed_s / max(base.batches, 1) * 1e6
    rows = []
    for i, (name, _, _) in enumerate(variants):
        rep = best[i]
        us = rep.elapsed_s / max(rep.batches, 1) * 1e6
        overhead = (us - base_us) / base_us * 100.0 if base_us > 0 else 0.0
        derived = (f"{rep.packets_per_second:,.0f}_pkt_per_s"
                   + ("" if i == 0 else f"_{overhead:+.1f}%_vs_baseline"))
        if rep.retries:
            derived += f"_{rep.retries}_retries"
        if rep.checkpoints_written:
            derived += f"_{rep.checkpoints_written}_ckpts"
        rows.append((f"fault_overhead_{name}", us, derived))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small windows: fast CI-sized run")
    ap.add_argument("--reps", type=int, default=None,
                    help="best-of-N per variant (default: 3 full, 1 quick)")
    args = ap.parse_args(argv)
    kw = (dict(window_log2=8, windows_per_batch=4, n_batches=8)
          if args.quick else {})
    kw["reps"] = args.reps or (1 if args.quick else 3)
    print("name,us_per_call,derived")
    for name, us, derived in run(**kw):
        print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
