"""Paper Fig. 2, GraphBLAS-only mode: hypersparse matrix build throughput.

The paper: 8 batches x 64 windows x 2^17 random src/dst pairs per window,
for 1/2/4/8 concurrent instances on the DPU's 8 ARM cores; peak 18M pkt/s
(~2.25M pkt/s/core).

Here: the same batch geometry through the unified ingest engine
(``repro.engine``, blocking policy) on the host device.  This container
exposes ONE CPU core, so the paper's process-scaling axis is emulated by
running N instances' workloads sequentially and reporting the aggregate
(per-instance contention is zero by construction; see EXPERIMENTS.md for
the honest read).  The per-core rate is the comparable number.
"""

from __future__ import annotations

import time

from repro.core.window import WindowConfig
from repro.engine import TrafficEngine


def run(window_log2: int = 17, windows_per_batch: int = 64,
        n_batches: int = 4, instances=(1, 2, 4, 8),
        anonymization: str = "feistel"):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)
    # The paper times build+merge only — leave the analytics stage out of
    # the jitted step so the measured rate is the paper's quantity.
    engine = TrafficEngine(cfg, policy="blocking",
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))
    # warmup/compile once; the jitted stage graph is shared by every run
    engine.run("uniform", n_batches=1, seed=99)

    rows = []
    for n_inst in instances:
        t0 = time.perf_counter()
        total_pkts = 0
        for inst in range(n_inst):
            rep = engine.run("uniform", n_batches=n_batches, seed=inst)
            total_pkts += rep.packets
        dt = time.perf_counter() - t0
        rate = total_pkts / dt
        us_per_window = dt / (n_inst * n_batches * windows_per_batch) * 1e6
        rows.append((f"fig2_graphblas_only_x{n_inst}", us_per_window,
                     f"{rate:,.0f}_pkt_per_s"))
    return rows
