"""Paper Fig. 2, GraphBLAS-only mode: hypersparse matrix build throughput.

The paper: 8 batches x 64 windows x 2^17 random src/dst pairs per window,
for 1/2/4/8 concurrent instances on the DPU's 8 ARM cores; peak 18M pkt/s
(~2.25M pkt/s/core).

Here: the same batch geometry through the unified ingest engine
(``repro.engine``, blocking policy) on the host device.  This container
exposes ONE CPU core, so the paper's process-scaling axis is emulated by
running N instances' workloads sequentially and reporting the aggregate
(per-instance contention is zero by construction; see EXPERIMENTS.md for
the honest read).  The per-core rate is the comparable number.

``--build-kernel`` routes the per-window builds through the fused Pallas
kernel (``kernels/build_fused``); stats are bit-identical, so the two
recorded JSONs (``fig2_graphblas_only.json`` vs
``fig2_graphblas_only_build_kernel.json``) are a pure before/after on the
build path.  ``--json-out``/``main`` mirror ``fig2_graphblas_io.py``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.window import WindowConfig
from repro.engine import TrafficEngine

RESULTS_DIR = Path(__file__).parent / "results_fig2"


def measure(window_log2: int = 17, windows_per_batch: int = 64,
            n_batches: int = 4, instances=(1, 2, 4, 8),
            anonymization: str = "feistel",
            build_kernel: bool = False) -> list[dict]:
    """The raw per-row measurements; ``run``/``run_json`` format these."""
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization,
                       build_kernel=build_kernel)
    # The paper times build+merge only — leave the analytics stage out of
    # the jitted step so the measured rate is the paper's quantity.
    engine = TrafficEngine(cfg, policy="blocking",
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))
    # warmup/compile once; the jitted stage graph is shared by every run
    engine.run("uniform", n_batches=1, seed=99)

    # default rows keep their historical names so recorded sweeps stay
    # comparable; the kernel rows carry an explicit tag
    tag = "_build_kernel" if build_kernel else ""
    records = []
    for n_inst in instances:
        t0 = time.perf_counter()
        total_pkts = 0
        for inst in range(n_inst):
            rep = engine.run("uniform", n_batches=n_batches, seed=inst)
            total_pkts += rep.packets
        dt = time.perf_counter() - t0
        rate = total_pkts / dt
        us_per_window = dt / (n_inst * n_batches * windows_per_batch) * 1e6
        records.append({
            "name": f"fig2_graphblas_only{tag}_x{n_inst}",
            "us_per_window": us_per_window,
            "pkt_per_s": rate,
        })
    return records


def run(window_log2: int = 17, windows_per_batch: int = 64,
        n_batches: int = 4, instances=(1, 2, 4, 8),
        anonymization: str = "feistel", build_kernel: bool = False):
    """Harness rows (name, us_per_call, derived-CSV cell)."""
    return [
        (r["name"], r["us_per_window"], f"{r['pkt_per_s']:,.0f}_pkt_per_s")
        for r in measure(window_log2=window_log2,
                         windows_per_batch=windows_per_batch,
                         n_batches=n_batches, instances=instances,
                         anonymization=anonymization,
                         build_kernel=build_kernel)
    ]


def run_json(build_kernel: bool = False, **kw) -> dict:
    """One build-path's curve as a self-describing JSON record."""
    return {
        "suite": "fig2_graphblas_only",
        "build_kernel": build_kernel,
        "geometry": {
            "window_log2": kw.get("window_log2", 17),
            "windows_per_batch": kw.get("windows_per_batch", 64),
            "n_batches": kw.get("n_batches", 4),
        },
        "rows": measure(build_kernel=build_kernel, **kw),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--build-kernel", action="store_true",
                    help="route builds through the fused Pallas kernel "
                         "(kernels/build_fused)")
    ap.add_argument("--quick", action="store_true",
                    help="small windows: fast CI-sized run")
    ap.add_argument("--window-log2", type=int, default=None)
    ap.add_argument("--windows-per-batch", type=int, default=None)
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--json-out", default=None,
                    help="write the record here (default benchmarks/"
                         "results_fig2/fig2_graphblas_only"
                         "[_build_kernel][_quick].json)")
    args = ap.parse_args(argv)

    kw = (dict(window_log2=12, windows_per_batch=8, n_batches=2,
               instances=(1, 2)) if args.quick else {})
    if args.window_log2 is not None:
        kw["window_log2"] = args.window_log2
    if args.windows_per_batch is not None:
        kw["windows_per_batch"] = args.windows_per_batch
    if args.batches is not None:
        kw["n_batches"] = args.batches
    record = run_json(build_kernel=args.build_kernel, **kw)
    # --quick defaults to a _quick artifact so a CI-sized run never
    # clobbers a recorded sweep; an explicit --json-out always wins
    tag = "_build_kernel" if args.build_kernel else ""
    default_name = (f"fig2_graphblas_only{tag}_quick.json" if args.quick
                    else f"fig2_graphblas_only{tag}.json")
    out = (Path(args.json_out) if args.json_out
           else RESULTS_DIR / default_name)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=2) + "\n")

    print("name,us_per_call,derived")
    for r in record["rows"]:
        print(f"{r['name']},{r['us_per_window']:.1f},"
              f"{r['pkt_per_s']:,.0f}_pkt_per_s")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
