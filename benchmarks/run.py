"""Benchmark harness: one module per paper table/figure + kernel and
roofline benches. Prints ``name,us_per_call,derived`` CSV.

The Fig.-2 suites (and the sharded-policy suite) all drive the unified
ingest engine (``repro.engine``), so their pkt/s numbers come from the same
telemetry (EngineReport) regardless of execution policy.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def _engine_sharded(window_log2: int = 15, windows_per_batch: int = 16,
                    n_batches: int = 2):
    """The sharded policy (mesh-parallel + exact all_to_all merge) through
    the same engine telemetry as the Fig.-2 curves."""
    from repro.core.window import WindowConfig
    from repro.engine import TrafficEngine

    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch)
    engine = TrafficEngine(cfg, policy="sharded")
    rep = engine.run("uniform", n_batches=n_batches + 1, seed=0,
                     warmup_items=1)
    return [(
        "engine_sharded",
        rep.elapsed_s / max(rep.batches, 1) * 1e6,
        f"{rep.packets_per_second:,.0f}_pkt_per_s",
    )]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small windows: fast CI-sized run")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (
        depth_sweep,
        fault_overhead,
        fig2_flow,
        fig2_graphblas_io,
        fig2_graphblas_only,
        kernels_bench,
        roofline,
        window_size_sweep,
    )

    quick = dict(window_log2=12, windows_per_batch=8, n_batches=2)
    suites = {
        "fig2_graphblas_only": lambda: fig2_graphblas_only.run(
            **(dict(quick, instances=(1, 2)) if args.quick else {})
        ),
        "fig2_graphblas_io": lambda: fig2_graphblas_io.run(
            **(dict(quick, thread_pairs=(1, 2)) if args.quick else {})
        ),
        "engine_sharded": lambda: _engine_sharded(
            **(quick if args.quick else {})
        ),
        "fig2_flow": lambda: fig2_flow.run(
            **(quick if args.quick else {})
        ),
        "window_size_sweep": lambda: window_size_sweep.run(
            **(dict(window_log2s=(10, 12), n_batches=2) if args.quick else {})
        ),
        "depth_sweep": lambda: depth_sweep.run(
            # quick harness runs never clobber the recorded full sweep;
            # full runs record it best-of-3 (reps interleaved across rows)
            # under results_depth/
            **(dict(window_log2=10, windows_per_batch=4, n_batches=4,
                    depths=(1, 2, 4), json_path=None) if args.quick
               else dict(reps=3))
        ),
        "fault_overhead": lambda: fault_overhead.run(
            **(dict(window_log2=8, windows_per_batch=4, n_batches=8)
               if args.quick else dict(reps=3))
        ),
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
    }

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row, us, derived in fn():
                print(f"{row},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness going
            failed += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
