"""The paper's OpenMP null-result, reproduced structurally: the per-window
work at 2^17 entries is too small to parallelize inside one build; rate
scales with window size until per-window overhead amortizes.

Sweeps window_log2 and reports pkt/s — the knee of this curve is the
"enough work per matrix" point; below it, launch overhead dominates (the
JAX analogue of OpenMP overhead swamping a 2^17-entry build).
"""

from __future__ import annotations

import time

import jax

from repro.core.window import WindowConfig, process_batch
from repro.data.packets import traffic_batches


def run(window_log2s=(13, 15, 17), windows_per_batch: int = 8,
        n_batches: int = 3):
    rows = []
    for wl in window_log2s:
        cfg = WindowConfig(window_log2=wl, windows_per_batch=windows_per_batch)

        @jax.jit
        def process(batch, cfg=cfg):
            merged, _, ovf = process_batch(batch, cfg)
            return merged.nnz

        warm = next(iter(traffic_batches(
            seed=9, n_batches=1, windows_per_batch=windows_per_batch,
            window_size=cfg.window_size)))
        jax.block_until_ready(process(warm))
        t0 = time.perf_counter()
        pkts = 0
        for batch in traffic_batches(
            seed=1, n_batches=n_batches,
            windows_per_batch=windows_per_batch,
            window_size=cfg.window_size,
        ):
            jax.block_until_ready(process(batch))
            pkts += batch.size // 2
        dt = time.perf_counter() - t0
        rows.append((
            f"window_size_2^{wl}",
            dt / (n_batches * windows_per_batch) * 1e6,
            f"{pkts/dt:,.0f}_pkt_per_s",
        ))
    return rows
