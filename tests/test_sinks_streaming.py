"""Streaming sinks: anomaly flagging (planted heavy-hitter window) and the
pcap-lite writer/reader round-trip, plus the triple-buffered preset."""

import numpy as np

from repro.core.build import matrix_build
from repro.core.window import WindowConfig
from repro.data.flows import FLOW_BYTES, FLOW_PKTS, FLOW_WIDTH
from repro.data.packets import PcapLite
from repro.engine import (
    AnomalySink,
    IterableSource,
    MatrixRetention,
    PcapLiteWriterSink,
    StatsAccumulator,
    TrafficEngine,
    TripleBufferedPolicy,
    make_policy,
)


def _cfg(**kw):
    kw.setdefault("window_log2", 5)
    kw.setdefault("windows_per_batch", 4)
    kw.setdefault("cap_max_log2", 9)
    return WindowConfig(**kw)


def _benign_flow_batches(cfg, n_batches):
    """Every window identical: one flow per distinct source (fan-out 1), so
    all across-window variance comes from whatever a test plants."""
    n = cfg.window_size
    window = np.zeros((n, FLOW_WIDTH), np.uint32)
    window[:, 0] = np.arange(n, dtype=np.uint32) + 1000  # distinct sources
    window[:, 1] = 7
    window[:, FLOW_BYTES] = 120
    window[:, FLOW_PKTS] = 2
    batch = np.broadcast_to(
        window, (cfg.windows_per_batch, n, FLOW_WIDTH)
    ).copy()
    return [batch.copy() for _ in range(n_batches)]


# -- AnomalySink ------------------------------------------------------------
def test_anomaly_sink_flags_exactly_the_planted_window():
    cfg = _cfg(anonymization="none")
    batches = _benign_flow_batches(cfg, n_batches=2)
    planted = cfg.windows_per_batch + 1  # batch 1, window 1 (global index 5)
    scan = batches[1][1]
    scan[:, 0] = 0xC0FFEE  # one source sweeping every destination
    scan[:, 1] = np.arange(cfg.window_size, dtype=np.uint32)

    eng = TrafficEngine(cfg, workload="flow",
                        sinks=[AnomalySink(threshold=2.5)])
    eng.run(IterableSource(it=batches))
    res = eng.finalize()["anomaly"]
    assert res["windows"] == 2 * cfg.windows_per_batch
    assert res["flagged"] == [planted]
    assert res["scores"][planted] >= 2.5
    benign = np.delete(res["scores"], planted)
    assert (benign < 2.5).all()


def test_anomaly_sink_all_benign_flags_nothing():
    cfg = _cfg(anonymization="none")
    eng = TrafficEngine(cfg, workload="flow",
                        sinks=[AnomalySink(threshold=2.5)])
    eng.run(IterableSource(it=_benign_flow_batches(cfg, 2)))
    res = eng.finalize()["anomaly"]
    # identical windows => zero variance => zero z-scores everywhere
    assert res["flagged"] == []
    assert (res["scores"] == 0).all()


def test_anomaly_sink_empty_run():
    sink = AnomalySink()
    res = sink.finalize()
    assert res["windows"] == 0
    assert res["flagged"] == []
    assert res["scores"].shape == (0,)  # uniform result shape when empty


def test_anomaly_sink_works_on_packet_workload(rng):
    """The fanout stage is workload-agnostic: the engine auto-appends it to
    the packet graph too."""
    cfg = _cfg(anonymization="none")
    eng = TrafficEngine(cfg, sinks=[AnomalySink(threshold=2.5)])
    eng.run("uniform", n_batches=2, seed=0)
    res = eng.finalize()["anomaly"]
    assert res["windows"] == 2 * cfg.windows_per_batch


# -- PcapLiteWriterSink -----------------------------------------------------
def test_pcap_writer_reader_round_trip(tmp_path, rng):
    """The written anonymized capture re-ingests (anonymization none) to
    bit-identical matrices — the sink's replay contract."""
    cfg = _cfg(anonymization="feistel")
    path = tmp_path / "anon.pcl"
    eng = TrafficEngine(
        cfg, sinks=[PcapLiteWriterSink(path=path),
                    MatrixRetention(max_keep=8)],
    )
    rep = eng.run("uniform", n_batches=2, seed=9)
    res = eng.finalize()
    assert res["pcap"]["packets"] == rep.packets

    cfg_replay = _cfg(anonymization="none")
    replay = TrafficEngine(cfg_replay, sinks=[MatrixRetention(max_keep=8)])
    rep2 = replay.run(str(path))
    assert rep2.batches == rep.batches
    for a, b in zip(res["matrices"], replay.finalize()["matrices"]):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.cols), np.asarray(b.cols))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
        assert int(a.nnz) == int(b.nnz)


def test_pcap_writer_flow_key_writes_flow_links(tmp_path):
    """For the flow workload the capture holds one anonymized (src, dst)
    pair per record; re-building counts records per link."""
    cfg = _cfg(anonymization="none")
    n = cfg.windows_per_batch * cfg.window_size
    flows = np.zeros((cfg.windows_per_batch, cfg.window_size, FLOW_WIDTH),
                     np.uint32)
    flows[..., 0] = 3
    flows[..., 1] = 4
    flows[..., FLOW_PKTS] = 10
    path = tmp_path / "flows.pcl"
    eng = TrafficEngine(cfg, workload="flow",
                        sinks=[PcapLiteWriterSink(path=path, key="flows")])
    eng.run(IterableSource(it=[flows]))
    assert eng.finalize()["pcap"]["packets"] == n

    pairs = PcapLite.read(path)
    A = matrix_build(np.asarray(pairs[:, 0]), np.asarray(pairs[:, 1]))
    assert int(A.nnz) == 1  # single link...
    r, c, v = A.entries()
    assert (r[0], c[0], v[0]) == (3, 4, n)  # ...seen once per record


# -- triple buffering -------------------------------------------------------
def test_triple_buffered_preset_depth_and_name():
    pol = make_policy("triple_buffered")
    assert isinstance(pol, TripleBufferedPolicy)
    assert pol.queue_depth == 3
    assert pol.name == "triple_buffered"


def test_deeper_queues_change_timing_never_stats():
    """blocking / double(2) / triple(3) / deep(7): identical per-batch stats
    and matrices; only the schedule (timing) may differ."""
    cfg = _cfg()
    traces, retained = [], []
    for policy in ("blocking", "double_buffered", "triple_buffered",
                   TripleBufferedPolicy(queue_depth=7)):
        eng = TrafficEngine(cfg, policy=policy,
                            sinks=[StatsAccumulator(),
                                   MatrixRetention(max_keep=8)])
        rep = eng.run("uniform", n_batches=3, seed=2, warmup_items=1)
        assert rep.batches == 2
        res = eng.finalize()
        traces.append(res["stats"]["per_batch"])
        retained.append(res["matrices"])

    base_trace, base_mats = traces[0], retained[0]
    for trace, mats in zip(traces[1:], retained[1:]):
        for a, b in zip(base_trace, trace):
            assert a.keys() == b.keys()
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
        for a, b in zip(base_mats, mats):
            np.testing.assert_array_equal(np.asarray(a.rows),
                                          np.asarray(b.rows))
            np.testing.assert_array_equal(np.asarray(a.vals),
                                          np.asarray(b.vals))


# -- file-handle discipline on failure paths --------------------------------
def test_pcap_writer_closes_on_engine_failure(tmp_path):
    """The satellite fix: a crashed run must not leak the writer's file
    handle (the conftest fd sanitizer backstops this), and what was
    written before the crash is a valid pcap-lite file."""
    import pytest

    from repro.checkpoint.framelog import open_tracked_files
    from repro.engine import FaultPlan, FaultTolerance

    cfg = _cfg(anonymization="none")
    path = tmp_path / "capture.rpcap"
    sink = PcapLiteWriterSink(path=str(path))
    eng = TrafficEngine(cfg, sinks=[StatsAccumulator(), sink])
    with pytest.raises(RuntimeError, match="injected crash"):
        eng.run("uniform", n_batches=4, seed=3,
                fault_tolerance=FaultTolerance(
                    plan=FaultPlan.parse("crash@2")))
    assert not [fh for fh in open_tracked_files()
                if getattr(fh, "name", "") == str(path)]
    # header count was back-patched at close: the partial file is readable
    pairs = PcapLite.read(path)
    assert pairs.shape == (2 * cfg.windows_per_batch * cfg.window_size, 2)


def test_pcap_writer_closes_on_worker_death(tmp_path):
    """Same discipline for BaseException-style deaths (WorkerKilled is not
    an Exception subclass)."""
    import pytest

    from repro.checkpoint.framelog import open_tracked_files
    from repro.engine import (FaultPlan, FaultTolerance, WorkerDiedError,
                              WorkerKilled)

    cfg = _cfg(anonymization="none")
    path = tmp_path / "capture.rpcap"
    eng = TrafficEngine(cfg, policy="triple_buffered",
                        sinks=[StatsAccumulator(),
                               PcapLiteWriterSink(path=str(path))])
    with pytest.raises((WorkerKilled, WorkerDiedError)):
        eng.run("uniform", n_batches=4, seed=3,
                fault_tolerance=FaultTolerance(
                    plan=FaultPlan.parse("kill-worker@2")))
    assert not [fh for fh in open_tracked_files()
                if getattr(fh, "name", "") == str(path)]


def test_pcap_writer_crash_resume_file_bit_identical(tmp_path):
    """Kill-and-resume produces the same capture file, byte for byte, as
    an uninterrupted run (the state_dict cursor truncates the torn tail)."""
    import pytest

    from repro.checkpoint.manager import CheckpointManager
    from repro.engine import FaultPlan, FaultTolerance

    cfg = _cfg(anonymization="none")
    ref_path = tmp_path / "ref.rpcap"
    eng = TrafficEngine(cfg, sinks=[StatsAccumulator(),
                                    PcapLiteWriterSink(path=str(ref_path))])
    eng.run("uniform", n_batches=6, seed=3)
    eng.finalize()

    path = tmp_path / "capture.rpcap"
    mgr = CheckpointManager(tmp_path / "ckpt")
    eng = TrafficEngine(cfg, sinks=[StatsAccumulator(),
                                    PcapLiteWriterSink(path=str(path))])
    with pytest.raises(RuntimeError, match="injected crash"):
        eng.run("uniform", n_batches=6, seed=3,
                fault_tolerance=FaultTolerance(
                    plan=FaultPlan.parse("crash@4")),
                checkpoint_every=2, checkpoint_manager=mgr)
    eng = TrafficEngine(cfg, sinks=[StatsAccumulator(),
                                    PcapLiteWriterSink(path=str(path))])
    eng.run("uniform", n_batches=6, seed=3,
            checkpoint_every=2, checkpoint_manager=mgr, resume=True)
    eng.finalize()
    assert path.read_bytes() == ref_path.read_bytes()


def test_pcap_writer_zero_batch_run_writes_valid_empty_file(tmp_path):
    cfg = _cfg(anonymization="none")
    path = tmp_path / "empty.rpcap"
    eng = TrafficEngine(cfg, sinks=[PcapLiteWriterSink(path=str(path))])
    eng.run("uniform", n_batches=0, seed=3)
    res = eng.finalize()["pcap"]
    assert res["packets"] == 0
    assert PcapLite.read(path).shape == (0, 2)
