"""Deterministic fault injection + the retry/quarantine survival layer.

Unit coverage for ``engine.faults`` (plans, the injector, RetryingSource's
retry/timeout/skip accounting, the quarantine dead-letter path) plus the
engine-level contract: a run that survives injected faults finalizes to
the same results as the fault-free run, with honest counters.
"""

import time

import numpy as np
import pytest

from repro.core.window import WindowConfig
from repro.engine import (
    FaultCounters,
    FaultPlan,
    FaultSpec,
    FaultTolerance,
    PermanentSourceError,
    PoisonedBatchError,
    QuarantineSink,
    RetryingSource,
    SinkWriteError,
    SourceTimeoutError,
    StatsAccumulator,
    TrafficEngine,
    TransientSourceError,
    WorkerKilled,
    make_batch_validator,
)
from repro.engine.faults import FaultInjectingSource
from repro.engine.source import IterableSource


def _cfg(**kw):
    kw.setdefault("window_log2", 6)
    kw.setdefault("windows_per_batch", 4)
    kw.setdefault("anonymization", "none")
    return WindowConfig(**kw)


def _items(n, windows=2, size=8):
    """n distinct, valid-looking batches."""
    return [np.full((windows, size, 2), i, np.uint32) for i in range(n)]


def _src(items):
    s = IterableSource(it=list(items))
    s.packets_per_item = int(np.prod(items[0].shape[:-1])) if items else None
    return s


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------
def test_fault_plan_parse():
    plan = FaultPlan.parse("transient:2@1, slow:0.05@2, poison@3, sink@2")
    assert plan.specs == (
        FaultSpec("transient", 1, count=2),
        FaultSpec("slow", 2, delay_s=0.05),
        FaultSpec("poison", 3),
        FaultSpec("sink", 2),
    )
    assert plan.sink_batches() == {2}
    assert all(s.kind != "sink" for s in plan.source_specs())
    assert not FaultPlan.parse("")
    with pytest.raises(ValueError, match="kind\\[:arg\\]@batch"):
        FaultPlan.parse("transient:2")
    with pytest.raises(ValueError, match="takes no argument"):
        FaultPlan.parse("poison:3@1")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("meteor@1")


def test_fault_plan_random_is_seed_keyed():
    a = FaultPlan.random(7, 50)
    b = FaultPlan.random(7, 50)
    c = FaultPlan.random(8, 50)
    assert a.specs == b.specs
    assert a.specs != c.specs
    assert a  # the default rates fire something over 50 batches
    assert {s.kind for s in a.specs} <= {"transient", "slow", "poison"}
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.random(0, 10, rates={"meteor": 1.0})


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor", 0)
    with pytest.raises(ValueError, match="batch"):
        FaultSpec("transient", -1)
    with pytest.raises(ValueError, match="count"):
        FaultSpec("transient", 0, count=0)


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------
def test_injector_transient_then_same_item():
    items = _items(3)
    counters = FaultCounters()
    inj = FaultInjectingSource(
        _src(items), FaultPlan.parse("transient:2@1"), counters=counters)
    it = iter(inj)
    got = [next(it)]
    for _ in range(2):
        with pytest.raises(TransientSourceError):
            next(it)
    got.extend(it)
    # the stream content is unchanged: retries re-attempt the same batch
    for a, b in zip(got, items):
        np.testing.assert_array_equal(a, b)
    assert counters.snapshot()["faults_injected"] == 2


def test_injector_permanent_raises_forever_counts_once():
    counters = FaultCounters()
    inj = FaultInjectingSource(
        _src(_items(2)), FaultPlan.parse("permanent@0"), counters=counters)
    it = iter(inj)
    for _ in range(3):
        with pytest.raises(PermanentSourceError):
            next(it)
    assert counters.snapshot()["faults_injected"] == 1


def test_injector_kill_worker_raises_base_exception():
    inj = FaultInjectingSource(_src(_items(2)),
                               FaultPlan.parse("kill-worker@0"))
    with pytest.raises(WorkerKilled):
        next(iter(inj))


def test_injector_skip_current_advances_past_the_fault():
    items = _items(3)
    inj = FaultInjectingSource(_src(items), FaultPlan.parse("permanent@1"))
    it = iter(inj)
    np.testing.assert_array_equal(next(it), items[0])
    with pytest.raises(PermanentSourceError):
        next(it)
    assert it.skip_current()  # disposes of stream item 1
    np.testing.assert_array_equal(next(it), items[2])
    with pytest.raises(StopIteration):
        next(it)
    assert not it.skip_current()  # already exhausted


def test_injector_poison_truncates_payload():
    inj = FaultInjectingSource(_src(_items(2)), FaultPlan.parse("poison@1"))
    good, bad = list(inj)
    assert good.shape[-1] == 2 and bad.shape[-1] == 1


# ---------------------------------------------------------------------------
# RetryingSource
# ---------------------------------------------------------------------------
def test_retry_survives_transient_with_accounting():
    items = _items(4)
    counters = FaultCounters()
    inj = FaultInjectingSource(
        _src(items), FaultPlan.parse("transient:2@1,transient:1@3"),
        counters=counters)
    retrier = RetryingSource(inj, max_retries=3, counters=counters)
    got = list(retrier)
    for a, b in zip(got, items):
        np.testing.assert_array_equal(a, b)
    snap = counters.snapshot()
    assert snap["retries"] == 3
    assert snap["faults_injected"] == 3
    assert snap["packets_dropped"] == 0
    # the checkpoint cursor: delivered index -> stream items consumed
    assert [retrier.delivered_pos(i) for i in range(4)] == [1, 2, 3, 4]


def test_retry_exhaustion_raises_the_original_error():
    inj = FaultInjectingSource(_src(_items(2)),
                               FaultPlan.parse("transient:5@0"))
    retrier = RetryingSource(inj, max_retries=2)
    with pytest.raises(TransientSourceError):
        list(retrier)
    assert retrier.counters.snapshot()["retries"] == 2


def test_retry_exhaustion_skip_drops_batch_with_accounting():
    items = _items(4)
    counters = FaultCounters()
    inj = FaultInjectingSource(
        _src(items), FaultPlan.parse("permanent@1"), counters=counters)
    retrier = RetryingSource(inj, max_retries=2, on_exhausted="skip",
                             counters=counters)
    got = list(retrier)
    assert len(got) == 3
    np.testing.assert_array_equal(got[1], items[2])
    snap = counters.snapshot()
    assert snap["packets_dropped"] == items[0].shape[0] * items[0].shape[1]
    # delivered items 0,1,2 consumed stream items 1, 3 (skip ate #1), 4
    assert [retrier.delivered_pos(i) for i in range(3)] == [1, 3, 4]


def test_retry_backoff_is_exponential():
    sleeps = []
    inj = FaultInjectingSource(_src(_items(1)),
                               FaultPlan.parse("transient:3@0"))
    retrier = RetryingSource(inj, max_retries=3, backoff_s=0.01,
                             sleep=sleeps.append)
    list(retrier)
    assert sleeps == [0.01, 0.02, 0.04]


def test_retry_does_not_swallow_worker_death():
    inj = FaultInjectingSource(_src(_items(2)),
                               FaultPlan.parse("kill-worker@0"))
    retrier = RetryingSource(inj, max_retries=5, on_exhausted="skip")
    with pytest.raises(WorkerKilled):
        list(retrier)


def test_retry_rejects_bad_config():
    with pytest.raises(ValueError, match="on_exhausted"):
        RetryingSource(_src(_items(1)), on_exhausted="explode")
    with pytest.raises(ValueError, match="max_retries"):
        RetryingSource(_src(_items(1)), max_retries=-1)


# ---------------------------------------------------------------------------
# per-attempt timeouts (the repro-retry-puller thread)
# ---------------------------------------------------------------------------
def _slow_gen(items, slow_at, delay_s):
    for i, item in enumerate(items):
        if i == slow_at:
            time.sleep(delay_s)
        yield item


def test_attempt_timeout_raises_after_retries():
    items = _items(3)
    retrier = RetryingSource(
        IterableSource(it=_slow_gen(items, 1, 0.6)),
        max_retries=1, attempt_timeout_s=0.05)
    it = iter(retrier)
    try:
        np.testing.assert_array_equal(next(it), items[0])
        with pytest.raises(SourceTimeoutError):
            next(it)
    finally:
        retrier.close()  # joins repro-retry-puller (thread-leak fixture)


def test_attempt_timeout_skip_abandons_the_hung_batch():
    # the hang (0.5s) must clear inside the NEXT batch's attempt window
    # (< 2 * 0.35s): the single puller thread serves pulls in order, so a
    # still-wedged read would charge the following batches' attempts too
    items = _items(3)
    src = _src([])
    src.it = _slow_gen(items, 1, 0.5)
    src.packets_per_item = int(np.prod(items[0].shape[:-1]))
    retrier = RetryingSource(src, max_retries=0, attempt_timeout_s=0.35,
                             on_exhausted="skip")
    try:
        got = list(retrier)
    finally:
        retrier.close()
    # the hung read was abandoned; its item (index 1) never delivered
    assert len(got) == 2
    np.testing.assert_array_equal(got[0], items[0])
    np.testing.assert_array_equal(got[1], items[2])
    assert retrier.counters.snapshot()["packets_dropped"] == (
        items[0].shape[0] * items[0].shape[1])


def test_timeout_mode_without_faults_is_transparent():
    items = _items(3)
    retrier = RetryingSource(_src(items), max_retries=2,
                             attempt_timeout_s=5.0)
    try:
        got = list(retrier)
    finally:
        retrier.close()
    for a, b in zip(got, items):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# validation + quarantine
# ---------------------------------------------------------------------------
def test_make_batch_validator_geometry():
    cfg = _cfg()
    v = make_batch_validator(cfg, "packets")
    ok = np.zeros((4, 64, 2), np.uint32)
    assert v(ok) is None
    assert "shape" in v(ok[..., :-1])
    assert "uint32" in v(ok.astype(np.int64))
    vf = make_batch_validator(cfg, "flow")
    assert vf(np.zeros((4, 64, 5), np.uint32)) is None
    assert "shape" in vf(ok)


def test_poisoned_batch_goes_to_quarantine():
    items = _items(4, windows=4, size=64)
    counters = FaultCounters()
    inj = FaultInjectingSource(
        _src(items), FaultPlan.parse("poison@2"), counters=counters)
    q = QuarantineSink()
    retrier = RetryingSource(
        inj, validator=make_batch_validator(_cfg(), "packets"),
        quarantine=q, counters=counters)
    got = list(retrier)
    assert len(got) == 3
    res = q.finalize()
    assert res["batches"] == 1
    entry = res["entries"][0]
    assert entry["index"] == 2 and "shape" in entry["reason"]
    assert entry["batch"].shape == (4, 64, 1)  # the truncated payload kept
    snap = counters.snapshot()
    assert snap["batches_quarantined"] == 1
    assert snap["packets_dropped"] == 4 * 64
    # stream cursor covers the quarantined item: delivered 0,1,2 at 1,2,4
    assert [retrier.delivered_pos(i) for i in range(3)] == [1, 2, 4]


def test_poisoned_batch_without_quarantine_raises():
    inj = FaultInjectingSource(_src(_items(2, windows=4, size=64)),
                               FaultPlan.parse("poison@0"))
    retrier = RetryingSource(
        inj, validator=make_batch_validator(_cfg(), "packets"))
    with pytest.raises(PoisonedBatchError, match="stream batch 0"):
        list(retrier)


# ---------------------------------------------------------------------------
# engine-level: survival == fault-free results, honest report
# ---------------------------------------------------------------------------
def _run_engine(ft=None, plan=None, sinks=None, **run_kw):
    engine = TrafficEngine(
        _cfg(), policy="blocking",
        sinks=sinks if sinks is not None else [StatsAccumulator()])
    if plan is not None:
        ft = FaultTolerance(plan=plan)
    rep = engine.run("uniform", n_batches=4, seed=11,
                     fault_tolerance=ft, **run_kw)
    return rep, engine.finalize()


def test_engine_survives_transients_bit_identically():
    rep_ref, ref = _run_engine()
    rep, res = _run_engine(plan=FaultPlan.parse("transient:2@0,transient@2"))
    assert rep.batches == rep_ref.batches == 4
    assert rep.packets == rep_ref.packets
    assert rep.retries == 3 and rep.faults_injected == 3
    assert rep.packets_dropped == 0
    a, b = ref["stats"], res["stats"]
    for k in a:
        if k == "per_batch":
            continue
        np.testing.assert_array_equal(a[k], b[k])
    assert "faults 3" in rep.summary()


def test_engine_quarantines_poison_and_reports_drop():
    ft = FaultTolerance(plan=FaultPlan.parse("poison@1"), validate=True)
    rep, res = _run_engine(ft=ft)
    assert rep.batches == 3  # one batch quarantined, stream continued
    assert rep.batches_quarantined == 1
    assert rep.packets_dropped == 4 * 64
    assert res["quarantine"]["batches"] == 1


def test_engine_sink_failure_record_vs_raise():
    plan = FaultPlan.parse("sink@1")
    ft = FaultTolerance(plan=plan, sink_failures="record")
    with pytest.warns(RuntimeWarning, match="sink 'stats' failed"):
        rep, res = _run_engine(ft=ft)
    assert rep.sink_write_failures == 1
    assert rep.batches == 4  # the run itself is whole
    assert res["stats"]["batches"] == 3  # the sink missed exactly one write

    with pytest.raises(SinkWriteError):
        _run_engine(ft=FaultTolerance(plan=plan))


# ---------------------------------------------------------------------------
# dead-letter journal: append-safe across crash/resume (no duplicates)
# ---------------------------------------------------------------------------
def test_quarantine_dead_letter_file_round_trip(tmp_path):
    path = tmp_path / "dead.rpfr"
    ft = FaultTolerance(plan=FaultPlan.parse("poison@1"),
                        quarantine_path=path)
    assert ft.validate  # quarantine_path implies validation
    engine = TrafficEngine(_cfg(), policy="blocking",
                           sinks=[StatsAccumulator()])
    rep = engine.run("uniform", n_batches=4, seed=11, fault_tolerance=ft)
    res = engine.finalize()
    assert rep.batches_quarantined == 1
    assert res["quarantine"]["path"] == str(path)

    from repro.checkpoint.framelog import FrameLog

    records = FrameLog.read_all(path)
    assert [k for k, _ in records] == [QuarantineSink.FRAME_KIND]
    rec = records[0][1]
    assert rec["index"] == 1 and "expected shape" in rec["reason"]
    np.testing.assert_array_equal(
        rec["batch"], np.asarray(res["quarantine"]["entries"][0]["batch"]))


def test_quarantine_log_is_append_safe_across_resume(tmp_path):
    """The satellite fix: a crash after the checkpoint that covered the
    dead-letter record must not duplicate it on resume — the journal ends
    bit-identical to an uncrashed run's."""
    from repro.checkpoint.framelog import FrameLog
    from repro.checkpoint.manager import CheckpointManager

    # reference: no crash, one poisoned batch -> one journal record
    ref_path = tmp_path / "ref.rpfr"
    engine = TrafficEngine(_cfg(), policy="blocking",
                           sinks=[StatsAccumulator()])
    engine.run("uniform", n_batches=6, seed=11, fault_tolerance=FaultTolerance(
        plan=FaultPlan.parse("poison@1"), quarantine_path=ref_path))
    ref_res = engine.finalize()
    ref_bytes = ref_path.read_bytes()

    # crashed run: poison@1 then crash@4; checkpoint_every=1 means the
    # record is covered by a checkpoint before the crash
    path = tmp_path / "dead.rpfr"
    mgr = CheckpointManager(tmp_path / "ckpt")
    engine = TrafficEngine(_cfg(), policy="blocking",
                           sinks=[StatsAccumulator()])
    with pytest.raises(RuntimeError, match="injected crash"):
        engine.run("uniform", n_batches=6, seed=11,
                   fault_tolerance=FaultTolerance(
                       plan=FaultPlan.parse("poison@1,crash@4"),
                       quarantine_path=path),
                   checkpoint_every=1, checkpoint_manager=mgr)
    assert len(FrameLog.read_all(path)) == 1  # journaled before the crash

    engine = TrafficEngine(_cfg(), policy="blocking",
                           sinks=[StatsAccumulator()])
    rep = engine.run("uniform", n_batches=6, seed=11,
                     fault_tolerance=FaultTolerance(quarantine_path=path),
                     checkpoint_every=1, checkpoint_manager=mgr,
                     resume=True)
    res = engine.finalize()
    assert rep.batches == 5 and rep.batches_quarantined == 1
    assert path.read_bytes() == ref_bytes  # no duplicate, bit-identical
    assert len(res["quarantine"]["entries"]) == 1
    a, b = ref_res["stats"], res["stats"]
    for k in a:
        if k == "per_batch":
            continue
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_quarantine_log_truncates_unckpted_tail_on_resume(tmp_path):
    """Records journaled after the last checkpoint are truncated away at
    resume and re-appended by the replay — still no duplicates."""
    from repro.checkpoint.framelog import FrameLog

    path = tmp_path / "dead.rpfr"
    sink = QuarantineSink(path=path)
    sink.quarantine(3, np.arange(4, dtype=np.uint32), "validation: bad")
    covered = sink.state_dict()  # checkpoint covers exactly one record
    sink.quarantine(5, np.arange(4, dtype=np.uint32), "validation: worse")
    assert len(FrameLog.read_all(path)) == 2
    sink.close()

    resumed = QuarantineSink(path=path)
    resumed.load_state_dict(covered)
    assert len(FrameLog.read_all(path)) == 1  # tail truncated
    resumed.quarantine(5, np.arange(4, dtype=np.uint32), "validation: worse")
    recs = FrameLog.read_all(path)
    assert [t["index"] for _, t in recs] == [3, 5]
    resumed.close()
