"""Dry-run machinery unit tests (no 512-device init): HLO collective
parsing, cell construction, roofline arithmetic."""


from repro.launch.dryrun import parse_collective_bytes

HLO_SAMPLE = """
ENTRY %main {
  %x = f32[4096,128]{1,0} parameter(0)
  %ar = f32[4096,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[32,16]<=[512], to_apply=%add
  %ag = bf16[2048]{0} all-gather(%y), channel_id=2, replica_groups=[16,32]<=[512], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%z), channel_id=3, replica_groups=[4,128]<=[512], to_apply=%add
  %cp = u32[1024]{0} collective-permute(%w), channel_id=4
  %aa = s32[64,16]{1,0} all-to-all(%v), channel_id=5, replica_groups=[8,64]<=[512]
}
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO_SAMPLE)
    c = out["counts"]
    assert c == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                 "all-to-all": 1, "collective-permute": 1}
    by = out["by_op_bytes"]
    # all-reduce: 2 * 4096*128*4 * 15/16
    assert abs(by["all-reduce"] - 2 * 4096 * 128 * 4 * 15 / 16) < 1
    # all-gather: 2048*2 * 31/32
    assert abs(by["all-gather"] - 2048 * 2 * 31 / 32) < 1
    # reduce-scatter: 256*4 * (128-1)
    assert abs(by["reduce-scatter"] - 256 * 4 * 127) < 1
    assert by["collective-permute"] == 1024 * 4
    assert by["all-to-all"] == 64 * 16 * 4
    assert out["per_device_bytes"] == sum(by.values())


def test_parse_ignores_non_collectives():
    assert parse_collective_bytes("%a = f32[8]{0} add(%b, %c)")[
        "per_device_bytes"
    ] == 0


def test_cells_constructible_without_mesh_devices():
    """Cell construction (shapes + specs) is pure metadata — no allocation,
    works on whatever mesh object is available."""
    import jax
    from repro import configs

    from repro.launch.mesh import make_mesh_from_plan

    mesh = make_mesh_from_plan((1, 1), ("data", "model"))
    for arch in ("llama3.2-1b", "gcn-cora", "two-tower-retrieval"):
        for shape in configs.get(arch).SHAPES:
            cell = configs.get(arch).build_cell(shape, mesh)
            leaves = jax.tree.leaves(cell.args)
            assert all(hasattr(l, "shape") for l in leaves)
            assert cell.model_flops_per_step > 0


def test_flops_model_sane_llama():
    from repro import configs
    import jax

    from repro.launch.mesh import make_mesh_from_plan

    mesh = make_mesh_from_plan((1, 1), ("data", "model"))
    cell = configs.get("llama3.2-1b").build_cell("train_4k", mesh)
    # 6 * ~1.5B * 1.05M tokens ~ 9.4e15
    assert 5e15 < cell.model_flops_per_step < 2e16
