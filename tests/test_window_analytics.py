"""Window pipeline: merge tree exactness + overflow audit; analytics vs
numpy oracles."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics, matrix_build
from repro.core.window import (
    WindowConfig,
    merge_tree,
    process_batch,
    process_windows_batched,
    window_slices,
)


def test_merge_tree_exact(rng):
    cfg = WindowConfig(window_log2=7, windows_per_batch=16,
                       cap_max_log2=12, anonymization="none")
    pkts = rng.integers(0, 60, (16 * 128, 2)).astype(np.uint32)
    wins = window_slices(jnp.asarray(pkts), cfg)
    merged, _, ovf = jax.jit(lambda w: process_batch(w, cfg))(wins)
    assert int(ovf) == 0
    ref = np.zeros((64, 64), np.int64)
    np.add.at(ref, (pkts[:, 0].astype(int), pkts[:, 1].astype(int)), 1)
    r, c, v = merged.entries()
    got = np.zeros((64, 64), np.int64)
    got[r.astype(int), c.astype(int)] = v
    assert np.array_equal(got, ref)


def test_merge_tree_overflow_is_counted(rng):
    cfg = WindowConfig(window_log2=7, windows_per_batch=8,
                       cap_max_log2=7, anonymization="none")  # tiny cap
    pkts = rng.integers(0, 5000, (8 * 128, 2)).astype(np.uint32)
    wins = window_slices(jnp.asarray(pkts), cfg)
    mats = process_windows_batched(wins, cfg)
    merged, ovf = merge_tree(mats, cfg)
    uniq = len({(int(a), int(b)) for a, b in pkts})
    # dropped + kept == distinct links
    assert int(ovf) + int(merged.nnz) == uniq
    assert int(ovf) > 0


def test_anonymization_invariant_stats(rng):
    cfg_plain = WindowConfig(window_log2=8, windows_per_batch=4,
                             cap_max_log2=11, anonymization="none")
    cfg_anon = WindowConfig(window_log2=8, windows_per_batch=4,
                            cap_max_log2=11, anonymization="feistel")
    pkts = rng.integers(0, 1 << 20, (4, 256, 2)).astype(np.uint32)
    w = jnp.asarray(pkts)
    m_plain = process_batch(w, cfg_plain)[0]
    m_anon = process_batch(w, cfg_anon)[0]
    s1 = analytics.window_stats(m_plain)
    s2 = analytics.window_stats(m_anon)
    for k in ("valid_packets", "unique_links", "unique_sources",
              "unique_destinations", "max_packets_per_link",
              "max_source_fanout", "max_dest_fanin"):
        assert int(s1[k]) == int(s2[k]), k


def test_analytics_vs_numpy(rng):
    src = rng.integers(0, 40, 2000).astype(np.uint32)
    dst = rng.integers(0, 40, 2000).astype(np.uint32)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=64, ncols=64)
    st = jax.jit(analytics.window_stats)(A)
    dense = np.zeros((64, 64), np.int64)
    np.add.at(dense, (src.astype(int), dst.astype(int)), 1)
    assert int(st["valid_packets"]) == 2000
    assert int(st["unique_links"]) == (dense > 0).sum()
    assert int(st["unique_sources"]) == (dense.sum(1) > 0).sum()
    assert int(st["unique_destinations"]) == (dense.sum(0) > 0).sum()
    assert int(st["max_packets_per_link"]) == dense.max()
    assert int(st["max_source_packets"]) == dense.sum(1).max()
    assert int(st["max_source_fanout"]) == (dense > 0).sum(1).max()
    assert int(st["max_dest_packets"]) == dense.sum(0).max()
    assert int(st["max_dest_fanin"]) == (dense > 0).sum(0).max()
    # histogram mass equals the number of active sources/dests
    assert int(st["src_packet_hist"].sum()) == (dense.sum(1) > 0).sum()
    assert int(st["dst_fanin_hist"].sum()) == (dense.sum(0) > 0).sum()


def test_top_k(rng):
    src = rng.integers(0, 30, 1000).astype(np.uint32)
    dst = rng.integers(0, 30, 1000).astype(np.uint32)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=32, ncols=32)
    dense = np.zeros((32, 32), np.int64)
    np.add.at(dense, (src.astype(int), dst.astype(int)), 1)
    r, c, v = analytics.top_k_heavy_hitters(A, 5)
    assert int(v[0]) == dense.max()
    ids, counts = analytics.top_k_sources(A, 3)
    assert int(counts[0]) == dense.sum(1).max()
    assert int(ids[0]) == dense.sum(1).argmax()


def test_stats_batched(rng):
    cfg = WindowConfig(window_log2=7, windows_per_batch=4,
                       anonymization="none")
    pkts = rng.integers(0, 100, (4, 128, 2)).astype(np.uint32)
    mats = process_windows_batched(jnp.asarray(pkts), cfg)
    st = jax.jit(analytics.window_stats_batched)(mats)
    assert st["valid_packets"].shape == (4,)
    assert (np.asarray(st["valid_packets"]) == 128).all()
