"""Fused build kernel vs the jnp oracle: bit-identity over the property
space (hypothesis + deterministic grid, as in test_engine_properties), the
nasty edges (valid SENTINEL keys, n_valid=0, all-dup/all-unique streams,
non-block-multiple n, vmap-over-windows), and the engine-equivalence
invariant with ``build_kernel`` enabled.

Everything runs in Pallas interpret mode on CPU (``default_interpret``);
the radix sort kernel is exercised explicitly via ``sort_mode="radix"`` at
sizes where interpret-mode per-bin loops stay fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.build import matrix_build
from repro.core.hypersparse import SENTINEL
from repro.core import types
from repro.kernels.build_fused import ops as fused_ops
from repro.kernels.build_fused.ref import fused_build_ref


def _streams(seed, n, ids, *, valued):
    r = np.random.default_rng(seed)
    rows = r.integers(0, ids, n, dtype=np.uint64).astype(np.uint32)
    cols = r.integers(0, ids, n, dtype=np.uint64).astype(np.uint32)
    vals = (r.integers(-100, 100, n).astype(np.int32) if valued else None)
    return rows, cols, vals


def _assert_bit_identical(got, want, label=""):
    for g, w, name in zip(got, want, ("rows", "cols", "vals", "nnz")):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{label}:{name}"
        )


def _check(seed, n, ids, n_valid, valued, sort_mode, block_size):
    rows, cols, vals = _streams(seed, n, ids, valued=valued)
    args = (jnp.asarray(rows), jnp.asarray(cols))
    if valued:
        args = args + (jnp.asarray(vals),)
    got = fused_ops.fused_build(
        *args, n_valid=n_valid, sort_mode=sort_mode, block_size=block_size
    )
    want = fused_build_ref(*args, n_valid=n_valid)
    _assert_bit_identical(
        got, want, f"seed={seed} n={n} ids={ids} nv={n_valid} "
        f"valued={valued} {sort_mode}/{block_size}"
    )


# -- hypothesis: fused == oracle over the property space --------------------
@given(
    st.integers(0, 2 ** 31 - 1),
    st.sampled_from([16, 100, 256, 1000]),
    st.sampled_from([1, 7, 1 << 8, 1 << 32]),
    st.sampled_from([None, 0.0, 0.4, 1.0]),
    st.booleans(),
    st.sampled_from(["xla", "radix"]),
    st.sampled_from([None, 128]),
)
@settings(max_examples=25, deadline=None)
def test_fused_matches_oracle_property(seed, n, ids, nv_frac, valued,
                                       sort_mode, block_size):
    n_valid = None if nv_frac is None else int(n * nv_frac)
    _check(seed, n, ids, n_valid, valued, sort_mode, block_size)


# -- deterministic floor: the same bit-identity without hypothesis ----------
@pytest.mark.parametrize("sort_mode", ["xla", "radix"])
@pytest.mark.parametrize("valued", [False, True])
@pytest.mark.parametrize("seed,n,ids,n_valid,block_size", [
    (0, 1000, 37, None, None),          # heavy duplicates, single block
    (1, 1000, 37, 700, 128),            # padding + cross-block carries
    (2, 777, 1 << 32, 500, 256),        # mostly unique, odd n
    (3, 512, 1, None, 128),             # one giant run (all-duplicate)
    (4, 512, 5, 0, None),               # n_valid = 0: empty matrix
    (5, 130, 1 << 16, 130, 128),        # non-block-multiple n, all valid
])
def test_fused_matches_oracle_grid(seed, n, ids, n_valid, valued,
                                   sort_mode, block_size):
    _check(seed, n, ids, n_valid, valued, sort_mode, block_size)


def test_all_unique_stream():
    """nnz == n: compaction is the identity, every slot is a run head."""
    n = 512
    rows = jnp.arange(n, dtype=jnp.uint32)
    cols = jnp.arange(n, dtype=jnp.uint32)
    got = fused_ops.fused_build(rows, cols, block_size=128)
    _assert_bit_identical(got, fused_build_ref(rows, cols))
    assert int(got[3]) == n
    assert np.asarray(got[2]).sum() == n


def test_valid_sentinel_key_is_not_padding():
    """255.255.255.255 is legal traffic: a valid (SENTINEL, SENTINEL)
    entry must survive the build as a real run, distinct from padding."""
    rows = jnp.full((64,), SENTINEL, jnp.uint32)
    cols = jnp.full((64,), SENTINEL, jnp.uint32)
    for mode in ("xla", "radix"):
        r, c, v, nnz = fused_ops.fused_build(
            rows, cols, n_valid=40, sort_mode=mode, block_size=128
        )
        assert int(nnz) == 1
        assert int(v[0]) == 40  # all 40 valid entries merge into one run
        assert int(r[0]) == 0xFFFFFFFF and int(c[0]) == 0xFFFFFFFF
        # padding slots keep the sentinel fill with zero values
        assert np.asarray(v[1:]).sum() == 0
    _assert_bit_identical(
        fused_ops.fused_build(rows, cols, n_valid=40),
        fused_build_ref(rows, cols, n_valid=40),
    )


def test_float_payload_close():
    """Float dup accumulation: scan order may differ from segment_sum, so
    the contract weakens to allclose (the engine's int32 path is exact)."""
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.integers(0, 50, 1024).astype(np.uint32))
    cols = jnp.asarray(rng.integers(0, 50, 1024).astype(np.uint32))
    vals = jnp.asarray(rng.standard_normal(1024).astype(np.float32))
    got = fused_ops.fused_build(rows, cols, vals, block_size=256)
    want = fused_build_ref(rows, cols, vals)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=2e-5, atol=1e-4)
    assert int(got[3]) == int(want[3])


def test_radix_sort_is_stable():
    """LSD radix == the stable variadic sort, payload order included:
    equal (row, col) keys keep their original payload order."""
    from repro.kernels.build_fused import kernel

    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.integers(0, 4, 256).astype(np.uint32))
    cols = jnp.asarray(rng.integers(0, 4, 256).astype(np.uint32))
    tag = jnp.arange(256, dtype=jnp.int32)  # original position as payload
    got = kernel.radix_sort_pairs(rows, cols, tag, interpret=True)
    want = jax.lax.sort((rows, cols, tag), num_keys=2, is_stable=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_vmap_over_windows():
    """The engine shape: vmapped fused build == vmapped oracle, with the
    cross-block SMEM carries exercised (block_size < window)."""
    rng = np.random.default_rng(9)
    pkts = jnp.asarray(
        rng.integers(0, 1 << 32, (4, 512, 2), dtype=np.uint32)
    )
    got = jax.jit(jax.vmap(
        lambda p: fused_ops.fused_build(p[:, 0], p[:, 1], block_size=128)
    ))(pkts)
    want = jax.vmap(lambda p: fused_build_ref(p[:, 0], p[:, 1]))(pkts)
    _assert_bit_identical(got, want, "vmap")


# -- through matrix_build: the use_kernel=True routing ----------------------
@pytest.mark.parametrize("valued", [False, True])
def test_matrix_build_use_kernel_bit_identical(rng, valued):
    src = rng.integers(0, 1 << 32, 2048, dtype=np.uint32)
    dst = rng.integers(0, 1 << 32, 2048, dtype=np.uint32)
    src[:5] = 0xFFFFFFFF
    dst[:5] = 0xFFFFFFFF
    vals = (jnp.asarray(rng.integers(1, 9, 2048).astype(np.int32))
            if valued else None)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), vals,
                     n_valid=2000, use_kernel=True)
    B = matrix_build(jnp.asarray(src), jnp.asarray(dst), vals,
                     n_valid=2000, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(A.rows), np.asarray(B.rows))
    np.testing.assert_array_equal(np.asarray(A.cols), np.asarray(B.cols))
    np.testing.assert_array_equal(np.asarray(A.vals), np.asarray(B.vals))
    assert int(A.nnz) == int(B.nnz)


def test_matrix_build_non_plus_monoid_keeps_jnp_path(rng):
    """use_kernel only claims the plus monoid; min/max still work and
    still match their jnp twins."""
    src = rng.integers(0, 10, 200).astype(np.uint32)
    dst = rng.integers(0, 10, 200).astype(np.uint32)
    vals = jnp.asarray(rng.integers(1, 100, 200).astype(np.int32))
    for monoid in (types.MIN_MONOID, types.MAX_MONOID):
        A = matrix_build(jnp.asarray(src), jnp.asarray(dst), vals,
                         nrows=10, ncols=10, dup=monoid, use_kernel=True)
        B = matrix_build(jnp.asarray(src), jnp.asarray(dst), vals,
                         nrows=10, ncols=10, dup=monoid, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(A.vals), np.asarray(B.vals))
        assert int(A.nnz) == int(B.nnz)


# -- the engine invariant with the kernel on --------------------------------
def _engine_outputs(cfg, workload, policy):
    from repro.engine import (
        MatrixRetention,
        StatsAccumulator,
        TrafficEngine,
    )

    eng = TrafficEngine(cfg, workload=workload, policy=policy,
                        sinks=[StatsAccumulator(), MatrixRetention(max_keep=4)])
    rep = eng.run("uniform", n_batches=2, seed=11)
    res = eng.finalize()
    return rep, res["stats"]["per_batch"], res["matrices"]


@pytest.mark.parametrize("workload", ["packets", "flow"])
def test_engine_equivalence_with_build_kernel(workload):
    """cfg.build_kernel=True must be invisible to every registered
    stage-graph policy: identical stats and retained matrices vs the
    blocking jnp reference (sharded policies route through the same
    cfg-driven helpers, covered by the stats subset assertion in
    test_engine_properties with any cfg)."""
    from repro.core.window import WindowConfig
    from repro.engine import ShardedPolicy, canonical_policies

    base = dict(window_log2=4, windows_per_batch=2, cap_max_log2=8,
                anonymization="none")
    cfg_jnp = WindowConfig(**base)
    cfg_krn = WindowConfig(**base, build_kernel=True)

    rb, tb, mb = _engine_outputs(cfg_jnp, workload, "blocking")
    for policy, cls in sorted(canonical_policies().items()):
        if issubclass(cls, ShardedPolicy):
            continue  # needs a device mesh axis; covered via helpers above
        rp, tp, mp = _engine_outputs(cfg_krn, workload, policy)
        assert rb.packets == rp.packets, policy
        assert rb.merge_overflow == rp.merge_overflow, policy
        for a, b in zip(tb, tp):
            assert a.keys() == b.keys()
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k], err_msg=f"{policy}:{k}"
                )
        for a, b in zip(mb, mp):
            np.testing.assert_array_equal(np.asarray(a.rows),
                                          np.asarray(b.rows))
            np.testing.assert_array_equal(np.asarray(a.cols),
                                          np.asarray(b.cols))
            np.testing.assert_array_equal(np.asarray(a.vals),
                                          np.asarray(b.vals))
            assert int(a.nnz) == int(b.nnz)


def test_sharded_policy_with_build_kernel():
    """The sharded path builds through cfg-driven helpers too: exact
    global stats must not care whether the kernel is on."""
    from repro.core.window import WindowConfig
    from repro.engine import StatsAccumulator, TrafficEngine

    base = dict(window_log2=4, windows_per_batch=2, cap_max_log2=8,
                anonymization="none")
    out = {}
    for flag in (False, True):
        eng = TrafficEngine(WindowConfig(**base, build_kernel=flag),
                            policy="sharded", sinks=[StatsAccumulator()])
        eng.run("uniform", n_batches=2, seed=11)
        out[flag] = eng.finalize()["stats"]["per_batch"]
    for a, b in zip(out[False], out[True]):
        for k in ("valid_packets", "unique_links", "unique_sources"):
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]), err_msg=k)
