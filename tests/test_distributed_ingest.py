"""Distributed ingest: the shard_map path and the exact all_to_all
row-block merge, validated on the local (1-device) mesh against direct
computation — the same code paths the 512-device dry-run lowers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytics
from repro.core.build import matrix_build
from repro.core.window import WindowConfig
from repro.launch.ingest import make_exact_ingest_step, run_paper_mode
from repro.launch.mesh import make_local_mesh


def _cfg():
    return WindowConfig(window_log2=8, windows_per_batch=2,
                        cap_max_log2=10, anonymization="none")


def test_exact_ingest_matches_direct(rng):
    cfg = _cfg()
    mesh = make_local_mesh()
    step = jax.jit(make_exact_ingest_step(mesh, cfg))
    w = rng.integers(0, 1 << 32, (mesh.size * 2, cfg.window_size, 2),
                     dtype=np.uint32)
    out = jax.block_until_ready(step(jnp.asarray(w)))

    flat = w.reshape(-1, 2)
    A = matrix_build(jnp.asarray(flat[:, 0]), jnp.asarray(flat[:, 1]))
    ref = analytics.window_stats(A)
    assert int(out["valid_packets"]) == flat.shape[0]
    assert int(out["unique_links"]) == int(ref["unique_links"])
    assert int(out["unique_sources"]) == int(ref["unique_sources"])
    assert int(out["max_source_fanout"]) == int(ref["max_source_fanout"])
    assert int(out["max_packets_per_link"]) == int(
        ref["max_packets_per_link"]
    )
    np.testing.assert_array_equal(
        np.asarray(out["src_fanout_hist"]), np.asarray(ref["src_fanout_hist"])
    )


def test_paper_modes_run(rng):
    rep_b = run_paper_mode("blocking", window_log2=8, windows_per_batch=2,
                           n_batches=2)
    rep_s = run_paper_mode("stream", window_log2=8, windows_per_batch=2,
                           n_batches=2)
    assert rep_b.packets == rep_s.packets == 2 * 2 * 256
    assert rep_b.packets_per_second > 0
    assert rep_s.packets_per_second > 0


def test_baseline_ingest_step_lowers_locally(rng):
    """The dry-run cell's step fn compiles and runs on the local mesh."""
    from repro.configs import traffic_matrix as tm

    cfg = _cfg()
    mesh = make_local_mesh()
    step = jax.jit(tm.make_ingest_step(mesh, cfg))
    w = rng.integers(0, 1 << 16, (mesh.size, cfg.window_size, 2),
                     dtype=np.uint32)
    out = jax.block_until_ready(step(jnp.asarray(w)))
    assert int(out["valid_packets"]) == mesh.size * cfg.window_size
