"""Substrate: optimizers, schedules, compression, checkpointing, data
pipeline, distributed control plane."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data.packets import PcapLite, traffic_batches, zipf_traffic
from repro.data.pipeline import Prefetcher
from repro.data.tokens import TokenStream
from repro.distributed.fault import (
    HeartbeatMonitor,
    StragglerPolicy,
    elastic_transition,
    plan_mesh,
)
from repro.optim import adamw, cosine_warmup, linear_warmup, sgd
from repro.optim.grad import (
    clip_by_global_norm,
    error_feedback_compress,
    global_norm,
    init_error_state,
    int8_compress,
    int8_decompress,
)


# -- optimizers ---------------------------------------------------------------
def test_adamw_first_step_math():
    """First AdamW step = -lr * (g/(|g|+eps) + wd*p) elementwise."""
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st0 = opt.init(p)
    p1, st1 = opt.update(g, p, st0, 0.01)
    # bias-corrected mhat = g, vhat = g^2 -> update = g/|g| = sign(g)
    expect = np.asarray([1.0, -2.0]) - 0.01 * (
        np.asarray([1.0, 1.0]) + 0.1 * np.asarray([1.0, -2.0])
    )
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-4)
    assert int(st1.step) == 1


def test_sgd_momentum():
    opt = sgd(momentum=0.5)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.ones(3)}
    st0 = opt.init(p)
    p1, st1 = opt.update(g, p, st0, 0.1)
    p2, st2 = opt.update(g, p1, st1, 0.1)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               1 - 0.1 - 0.1 * 1.5, rtol=1e-5)


def test_convergence_quadratic():
    """AdamW minimizes a quadratic — sanity that the update math descends."""
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, state = opt.update(g, p, state, 0.1)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_schedules():
    f = linear_warmup(1.0, 10)
    assert float(f(0)) < float(f(5)) < float(f(20)) == 1.0
    g = cosine_warmup(1.0, 10, 100)
    assert float(g(99)) < float(g(20))
    assert abs(float(g(10 ** 6)) - 0.1) < 1e-5


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) == 20.0


# -- compression ----------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128).astype(np.float32) * 10)
    q, s = int8_compress(x)
    back = int8_decompress(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_telescopes(rng):
    """Sum of dequantized payloads + final error == sum of raw gradients."""
    params = {"w": jnp.zeros(64)}
    err = init_error_state(params)
    total_raw = np.zeros(64, np.float32)
    total_deq = np.zeros(64, np.float32)
    for i in range(5):
        g = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        payload, scales, err = error_feedback_compress(g, err)
        total_raw += np.asarray(g["w"])
        total_deq += np.asarray(int8_decompress(payload["w"], scales["w"]))
    resid = total_raw - (total_deq + np.asarray(err["w"]))
    np.testing.assert_allclose(resid, 0, atol=1e-4)


# -- checkpoint ----------------------------------------------------------------
def test_pytree_roundtrip(rng):
    tree = {
        "a": jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32)),
        "b": [jnp.int32(7), None],
        "c": {"d": jnp.asarray(rng.integers(0, 5, 6, dtype=np.int32))},
    }
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, f"{d}/x.rpck", meta={"k": 1})
        back, meta = load_pytree(f"{d}/x.rpck", like=tree)
        assert meta == {"k": 1}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_retention_and_restart(rng):
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, jax.tree.map(lambda x: x * s, state))
        assert mgr.steps() == [3, 4]
        restored, meta = mgr.restore(state)
        assert meta["step"] == 4
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.arange(8) * 4)
        # structure mismatch is rejected, not silently mis-restored
        try:
            mgr.restore({"other": state["w"], "second": state["w"]})
            raised = False
        except ValueError:
            raised = True
        assert raised


def test_checkpoint_crash_safety(rng):
    """A .tmp from a crashed save never shadows the latest checkpoint."""
    state = {"w": jnp.ones(4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(1, state)
        (mgr.dir / "ckpt_0000000002.tmp").write_bytes(b"garbage")
        assert mgr.latest_step() == 1
        restored, _ = mgr.restore(state)
        assert restored is not None


# -- data ----------------------------------------------------------------------
def test_token_stream_exact_resume():
    s1 = TokenStream(7, 500, 2, 8)
    batches = [next(s1) for _ in range(5)]
    s2 = TokenStream.from_state(
        {"seed": 7, "step": 3, "vocab_size": 500, "batch": 2, "seq_len": 8}
    )
    np.testing.assert_array_equal(batches[3][0], next(s2)[0])


def test_pcap_roundtrip_and_stream(rng, tmp_path):
    pkts = zipf_traffic(rng, 1000)
    PcapLite.write(tmp_path / "t.pcl", pkts)
    assert np.array_equal(PcapLite.read(tmp_path / "t.pcl"), pkts)
    wins = list(PcapLite.stream_windows(tmp_path / "t.pcl", 256))
    assert len(wins) == 3 and wins[0].shape == (256, 2)


def test_traffic_batches_deterministic():
    a = list(traffic_batches(seed=1, n_batches=2, windows_per_batch=2,
                             window_size=16))
    b = list(traffic_batches(seed=1, n_batches=2, windows_per_batch=2,
                             window_size=16))
    np.testing.assert_array_equal(a[1], b[1])


def test_prefetcher_error_propagation():
    def gen():
        yield 1
        raise RuntimeError("boom")

    pf = Prefetcher(gen())
    assert next(pf) == 1
    try:
        next(pf)
        raised = False
    except RuntimeError:
        raised = True
    assert raised


# -- fault control plane -------------------------------------------------------
def test_straggler_lifecycle():
    mon = HeartbeatMonitor(3, dead_after_s=5.0)
    for step in range(6):
        mon.beat(0, step, 1.0, now=step)
        mon.beat(1, step, 1.0, now=step)
        mon.beat(2, step, 10.0, now=step)
    pol = StragglerPolicy(mon, drop_after_straggles=2)
    assert pol.evaluate(now=5.0).action == "proceed"
    d = pol.evaluate(now=5.5)
    assert d.action == "drop" and d.hosts == (2,)
    assert abs(d.grad_rescale - 1.5) < 1e-9
    # now host 2 stops beating entirely -> evict
    for step in range(6, 9):
        mon.beat(0, step, 1.0, now=step)
        mon.beat(1, step, 1.0, now=step)
    d2 = pol.evaluate(now=20.0)
    assert d2.action == "evict" and 2 in d2.hosts


def test_never_beaten_host_can_die():
    """A host that registers but never heartbeats counts its silence from
    registration — it must not be immortal (the wedge-before-first-beat
    failure mode)."""
    mon = HeartbeatMonitor(2, dead_after_s=5.0, now=0.0)
    mon.beat(0, 0, 1.0, now=3.0)
    assert mon.dead(now=4.0) == []      # neither host past the deadline yet
    assert mon.dead(now=6.0) == [1]     # host 1 silent since registration
    assert mon.dead(now=9.0) == [0, 1]  # host 0's last beat now stale too


def test_elastic_plans():
    assert plan_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256) == ((16, 16), ("data", "model"))
    assert plan_mesh(768, devices_per_pod=256) == (
        (3, 16, 16), ("pod", "data", "model")
    )
    tr = elastic_transition(range(512), [0])
    assert tr["mesh_shape"] == (31, 16)
    assert len(tr["devices"]) == 496 and len(tr["idle"]) == 15


def test_sharding_batch_axes():
    from repro.distributed.sharding import batch_axes_for
    from repro.launch.mesh import make_mesh_from_plan

    mesh = make_mesh_from_plan((1, 1), ("data", "model"))
    assert batch_axes_for(7, mesh) == "data"  # size-1 axis divides anything
