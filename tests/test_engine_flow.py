"""The Suricata-flow workload: value-carrying build/merge conservation,
EVE-JSON-lite round-trips, flow sources, and the sharded flow path."""

import numpy as np
import pytest

from repro.core.window import WindowConfig
from repro.data.flows import (
    FLOW_BYTES,
    FLOW_PKTS,
    FLOW_WIDTH,
    eve_read,
    eve_write,
    flow_batches,
    ip_to_u32,
    synthetic_flows,
    u32_to_ip,
)
from repro.engine import (
    IterableSource,
    MatrixRetention,
    StatsAccumulator,
    SuricataFlowSource,
    TrafficEngine,
)


def _cfg(**kw):
    kw.setdefault("window_log2", 5)
    kw.setdefault("windows_per_batch", 4)
    kw.setdefault("cap_max_log2", 9)
    return WindowConfig(**kw)


def _matrix_sum(m) -> int:
    valid = np.arange(m.rows.shape[0]) < int(m.nnz)
    return int(np.asarray(m.vals)[valid].astype(np.int64).sum())


# -- round-trip conservation: sum(matrix values) == sum(input payloads) -----
@pytest.mark.parametrize("anonymization", ["none", "feistel"])
def test_flow_payload_conservation_exact(anonymization):
    cfg = _cfg(anonymization=anonymization)
    eng = TrafficEngine(
        cfg, workload="flow",
        sinks=[StatsAccumulator(), MatrixRetention(max_keep=8),
               MatrixRetention(key="byte_matrix", max_keep=8)],
    )
    rep = eng.run("uniform", n_batches=3, seed=11)
    assert rep.merge_overflow == 0
    res = eng.finalize()

    batches = list(flow_batches(11, n_batches=3,
                                windows_per_batch=cfg.windows_per_batch,
                                window_size=cfg.window_size))
    for i, batch in enumerate(batches):
        in_pkts = int(batch[..., FLOW_PKTS].astype(np.int64).sum())
        in_bytes = int(batch[..., FLOW_BYTES].astype(np.int64).sum())
        assert _matrix_sum(res["matrices"][i]) == in_pkts
        assert _matrix_sum(res["byte_matrix"][i]) == in_bytes

    # and the stats trace agrees: valid_packets of the flow matrix is the
    # true packet total, not the record count
    total_pkts = sum(int(b[..., FLOW_PKTS].astype(np.int64).sum())
                     for b in batches)
    assert int(res["stats"]["valid_packets"]) == total_pkts


def test_flow_merge_overflow_reported_not_silent():
    # 4 windows x 32 all-unique links = 128 unique into a 64-entry cap:
    # conservation must break by exactly the audited amount (dropped
    # entries are counted, never silently truncated)
    cfg = _cfg(cap_max_log2=6, anonymization="none")
    n = cfg.windows_per_batch * cfg.window_size
    flows = np.zeros((cfg.windows_per_batch, cfg.window_size, FLOW_WIDTH),
                     np.uint32)
    coords = np.arange(2 * n, dtype=np.uint32).reshape(n, 2)
    flows[..., :2] = coords.reshape(cfg.windows_per_batch, cfg.window_size, 2)
    flows[..., FLOW_PKTS] = 3
    flows[..., FLOW_BYTES] = 120

    eng = TrafficEngine(cfg, workload="flow",
                        sinks=[MatrixRetention(max_keep=1)])
    rep = eng.run(IterableSource(it=[flows]))
    assert rep.merge_overflow == 64  # 128 unique into cap 64
    kept = _matrix_sum(eng.finalize()["matrices"][0])
    # every link carries exactly 3 packets, so the dropped mass is exactly
    # 3 * overflow
    assert kept == 3 * n - 3 * rep.merge_overflow


def test_flow_source_records_and_rate_accounting():
    cfg = _cfg()
    eng = TrafficEngine(cfg, workload="flow", sinks=[StatsAccumulator()])
    rep = eng.run("uniform", n_batches=2, seed=0)
    assert rep.batches == 2
    # flow workloads count records: W * n per batch
    assert rep.packets == 2 * cfg.windows_per_batch * cfg.window_size
    totals = eng.finalize()["stats"]
    assert totals["batches"] == 2


def test_flow_zipf_source_accumulates_duplicates():
    cfg = _cfg(anonymization="none")
    eng = TrafficEngine(cfg, workload="flow",
                        sinks=[StatsAccumulator(), MatrixRetention()])
    eng.run("zipf", n_batches=1, seed=5)
    res = eng.finalize()
    m = res["matrices"][0]
    n_records = cfg.windows_per_batch * cfg.window_size
    # heavy-tailed addresses repeat links; values still conserve
    assert int(res["stats"]["unique_links"]) <= n_records
    batch = next(flow_batches(5, n_batches=1,
                              windows_per_batch=cfg.windows_per_batch,
                              window_size=cfg.window_size, kind="zipf"))
    assert _matrix_sum(m) == int(batch[..., FLOW_PKTS].astype(np.int64).sum())


# -- EVE-JSON-lite ----------------------------------------------------------
def test_eve_json_round_trip(rng, tmp_path):
    flows = synthetic_flows(rng, 64, kind="uniform")
    path = tmp_path / "eve.json"
    eve_write(path, flows)
    back = eve_read(path)
    np.testing.assert_array_equal(back, flows)


def test_eve_read_skips_non_flow_events(rng, tmp_path):
    flows = synthetic_flows(rng, 8)
    path = tmp_path / "eve.json"
    eve_write(path, flows)
    text = path.read_text()
    path.write_text(
        '{"event_type": "alert", "src_ip": "10.0.0.1"}\n'
        + "not json at all\n\n" + text
    )
    np.testing.assert_array_equal(eve_read(path), flows)


def test_eve_read_clamps_payloads_to_int32_range(tmp_path):
    """Payloads beyond int32 saturate at ingest instead of wrapping
    negative through the device's int32 matrix values, and corrupt
    negative counts floor at 0 instead of crashing the uint32 cast."""
    import json

    path = tmp_path / "eve.json"
    path.write_text(
        json.dumps({
            "event_type": "flow", "src_ip": "10.0.0.1",
            "dest_ip": "10.0.0.2",
            "flow": {"bytes_toserver": 3_000_000_000, "pkts_toserver": 12,
                     "state": "closed"},
        }) + "\n" + json.dumps({
            "event_type": "flow", "src_ip": "10.0.0.3",
            "dest_ip": "10.0.0.4",
            "flow": {"bytes_toserver": -5, "pkts_toserver": -1,
                     "state": "new"},
        }) + "\n")
    rec = eve_read(path)
    assert rec[0, FLOW_BYTES] == 0x7FFFFFFF
    assert rec[0, FLOW_PKTS] == 12
    assert rec[1, FLOW_BYTES] == 0
    assert rec[1, FLOW_PKTS] == 0


def test_ip_conversion_round_trip():
    for v in (0, 1, 0xC0A80101, 0xFFFFFFFF):
        assert ip_to_u32(u32_to_ip(v)) == v
    assert ip_to_u32("192.168.1.1") == 0xC0A80101


def test_suricata_flow_source_replay_matches_synthetic(rng, tmp_path):
    """EVE file -> SuricataFlowSource == the same records via IterableSource
    (trailing partial batch dropped, like the pcap replayer)."""
    cfg = _cfg(anonymization="none")
    per_batch = cfg.windows_per_batch * cfg.window_size
    flows = synthetic_flows(rng, 2 * per_batch + 7)
    path = tmp_path / "eve.json"
    eve_write(path, flows)

    eng_file = TrafficEngine(cfg, workload="flow",
                             sinks=[StatsAccumulator(), MatrixRetention()])
    rep = eng_file.run(str(path))
    assert rep.batches == 2
    assert isinstance(eng_file.make_source(str(path)), SuricataFlowSource)

    whole = flows[: 2 * per_batch].reshape(
        2, cfg.windows_per_batch, cfg.window_size, FLOW_WIDTH
    )
    eng_mem = TrafficEngine(cfg, workload="flow",
                            sinks=[StatsAccumulator(), MatrixRetention()])
    eng_mem.run(IterableSource(it=list(whole)))

    for a, b in zip(eng_file.finalize()["matrices"],
                    eng_mem.finalize()["matrices"]):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))


# -- sharded flow path ------------------------------------------------------
def test_sharded_flow_matches_blocking_exactly():
    cfg = _cfg(windows_per_batch=2, anonymization="none")
    eb = TrafficEngine(cfg, workload="flow", policy="blocking",
                       sinks=[StatsAccumulator()])
    eb.run("uniform", n_batches=2, seed=3)
    es = TrafficEngine(cfg, workload="flow", policy="sharded",
                       sinks=[StatsAccumulator()])
    rep = es.run("uniform", n_batches=2, seed=3)
    assert rep.policy == "sharded"

    shared = ("valid_packets", "unique_links", "unique_sources",
              "max_packets_per_link", "max_source_packets",
              "max_source_fanout", "src_packet_hist", "src_fanout_hist")
    tb = eb.finalize()["stats"]["per_batch"]
    ts = es.finalize()["stats"]["per_batch"]
    for a, b in zip(tb, ts):
        for k in shared:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                          err_msg=k)


def test_unknown_workload_rejected():
    with pytest.raises(ValueError, match="workload"):
        TrafficEngine(_cfg(), workload="quantum")
