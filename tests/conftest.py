import sys
import types

import numpy as np
import pytest

try:
    from hypothesis import settings

    # CPU-contention-friendly hypothesis defaults (the dry-run sweep may be
    # running concurrently on this single-core container)
    settings.register_profile("repro", max_examples=25, deadline=None)
    settings.load_profile("repro")
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    # hypothesis is an optional dev dependency (see requirements-dev.txt).
    # Install a stub so modules that mix property tests with plain oracle
    # tests still import and run; @given tests auto-skip at call time.
    class _Strategy:
        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    def _given(*args, **kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @classmethod
        def register_profile(cls, *args, **kwargs):
            pass

        @classmethod
        def load_profile(cls, *args, **kwargs):
            pass

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _Settings
    _stub.assume = lambda *a, **k: True
    _stub.HealthCheck = _Strategy()
    _stub.strategies = _Strategy()
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _Strategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)
