import os
import sys
import threading
import time
import types

import numpy as np
import pytest

import jax

# -- runtime sanitizers ------------------------------------------------------
# The whole suite runs with JAX's strictest numerics modes, mirroring the CI
# env (.github/workflows/ci.yml).  Rank promotion and implicit dtype
# promotion are exactly the bug classes the uint32 packed-key math cannot
# survive silently (a u32 column widening to i64 breaks the x64-disabled
# build path), so any op relying on either fails loudly here.
jax.config.update("jax_numpy_rank_promotion", "raise")
jax.config.update("jax_numpy_dtype_promotion", "strict")
# NaN-checking reruns every jitted computation un-jitted on NaN output,
# which is far too slow to leave on by default — opt in per-run:
#   REPRO_DEBUG_NANS=1 python -m pytest ...
if os.environ.get("REPRO_DEBUG_NANS"):
    jax.config.update("jax_debug_nans", True)

try:
    from hypothesis import settings

    # CPU-contention-friendly hypothesis defaults (the dry-run sweep may be
    # running concurrently on this single-core container)
    settings.register_profile("repro", max_examples=25, deadline=None)
    settings.load_profile("repro")
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    # hypothesis is an optional dev dependency (see requirements-dev.txt).
    # Install a stub so modules that mix property tests with plain oracle
    # tests still import and run; @given tests auto-skip at call time.
    class _Strategy:
        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    def _given(*args, **kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @classmethod
        def register_profile(cls, *args, **kwargs):
            pass

        @classmethod
        def load_profile(cls, *args, **kwargs):
            pass

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _Settings
    _stub.assume = lambda *a, **k: True
    _stub.HealthCheck = _Strategy()
    _stub.strategies = _Strategy()
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _Strategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# -- thread-leak sanitizer ---------------------------------------------------
# Every repro-owned worker thread is named "repro-*" (see
# engine/prefetch.py); the engine contract is that no such thread outlives
# the pipeline that spawned it (BoundedPrefetcher.close() in the policies'
# ``finally`` blocks).  This autouse fixture turns a violation into a test
# failure at the offending test, instead of a flaky hang three tests later.


def _leakable(t: threading.Thread) -> bool:
    return t.is_alive() and (
        not t.daemon or (t.name or "").startswith("repro-")
    )


@pytest.fixture(autouse=True)
def _no_thread_leaks():
    before = {id(t) for t in threading.enumerate()}
    yield
    leaked = [t for t in threading.enumerate()
              if id(t) not in before and _leakable(t)]
    if not leaked:
        return
    # a just-exhausted prefetcher's worker may still be inside its final
    # put/return; give stragglers one grace interval before declaring a leak
    deadline = time.monotonic() + 1.0
    for t in leaked:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    leaked = [t for t in leaked if t.is_alive()]
    if leaked:
        names = ", ".join(f"{t.name} (daemon={t.daemon})" for t in leaked)
        pytest.fail(
            f"test leaked {len(leaked)} thread(s): {names} — pipelines "
            f"must close their prefetchers (BoundedPrefetcher.close())"
        )


# -- fd-leak sanitizer -------------------------------------------------------
# Sibling of the thread-leak check, for file-backed sinks: every file handle
# a repro sink/journal opens registers via checkpoint.framelog.track_file.
# The engine contract (Sink.close) is that no handle survives a run — not
# even a *failed* run — so any tracked handle still open after a test is a
# leak at the offending test.


@pytest.fixture(autouse=True)
def _no_fd_leaks():
    from repro.checkpoint.framelog import open_tracked_files

    before = {id(fh) for fh in open_tracked_files()}
    yield
    leaked = [fh for fh in open_tracked_files() if id(fh) not in before]
    if leaked:
        names = ", ".join(getattr(fh, "name", "<unknown>") for fh in leaked)
        pytest.fail(
            f"test leaked {len(leaked)} open file handle(s): {names} — "
            f"file-backed sinks must close on every engine exit path "
            f"(Sink.close / finalize)"
        )
