import numpy as np
import pytest
from hypothesis import settings

# CPU-contention-friendly hypothesis defaults (the dry-run sweep may be
# running concurrently on this single-core container)
settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
