"""The unified ingest engine: policy equivalence (the Fig.-2 contract),
source plug-ins, stage-graph validation, sinks, and the shared
packet-accounting rule."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.build import matrix_build
from repro.core.window import WindowConfig
from repro.engine import (
    IterableSource,
    MatrixRetention,
    StatsAccumulator,
    TopKHeavyHitters,
    TrafficEngine,
    packets_in_item,
)
from repro.engine.stages import StageGraph
from repro.engine.telemetry import EngineReport


def _cfg(**kw):
    kw.setdefault("window_log2", 6)
    kw.setdefault("windows_per_batch", 4)
    kw.setdefault("cap_max_log2", 9)
    return WindowConfig(**kw)


def _stats_trace(engine):
    return engine.finalize()["stats"]["per_batch"]


# -- the acceptance contract: policies agree on analytics, differ only in
#    schedule ---------------------------------------------------------------
def test_blocking_and_double_buffered_identical_stats():
    cfg = _cfg()
    reports, traces = {}, {}
    for policy in ("blocking", "double_buffered"):
        eng = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
        reports[policy] = eng.run("uniform", n_batches=4, seed=7,
                                  warmup_items=1)
        traces[policy] = _stats_trace(eng)

    rb, rd = reports["blocking"], reports["double_buffered"]
    assert rb.batches == rd.batches == 3
    assert rb.packets == rd.packets == 3 * 4 * 64
    assert rb.packets_per_second > 0 and rd.packets_per_second > 0
    assert rb.policy == "blocking" and rd.policy == "double_buffered"

    for a, b in zip(traces["blocking"], traces["double_buffered"]):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_sharded_policy_matches_blocking_exactly():
    cfg = _cfg(windows_per_batch=2, anonymization="none")
    eb = TrafficEngine(cfg, policy="blocking", sinks=[StatsAccumulator()])
    eb.run("uniform", n_batches=2, seed=3)
    es = TrafficEngine(cfg, policy="sharded", sinks=[StatsAccumulator()])
    rep_s = es.run("uniform", n_batches=2, seed=3)

    assert rep_s.policy == "sharded"
    shared_keys = ("valid_packets", "unique_links", "unique_sources",
                   "max_packets_per_link", "max_source_packets",
                   "max_source_fanout", "src_packet_hist",
                   "src_fanout_hist")
    for a, b in zip(_stats_trace(eb), _stats_trace(es)):
        for k in shared_keys:
            np.testing.assert_array_equal(a[k], b[k])


# -- packet accounting: one rule everywhere ---------------------------------
def test_packets_in_item_rule():
    batch = np.zeros((4, 64, 2), np.uint32)
    window = np.zeros((64, 2), np.uint32)
    assert packets_in_item(batch) == 4 * 64
    assert packets_in_item(window) == 64
    assert packets_in_item(batch, packets_per_item=17) == 17
    assert packets_in_item(object()) == 0


def test_stream_shims_share_the_rule():
    """run_blocking/run_stream infer rates identically (the old code
    multiplied different axes in each loop)."""
    from repro.core import stream

    assert stream.packets_in_item is packets_in_item
    assert stream.StreamReport is EngineReport

    batches = [np.zeros((2, 32, 2), np.uint32) for _ in range(3)]
    rep_b = stream.run_blocking(iter(batches), lambda x: x.sum())
    rep_s = stream.run_stream(iter(batches), lambda x: x.sum())
    assert rep_b.packets == rep_s.packets == 3 * 2 * 32


# -- sources ----------------------------------------------------------------
def test_pcaplite_source_replay(rng, tmp_path):
    from repro.data.packets import PcapLite

    cfg = _cfg(windows_per_batch=2, anonymization="none")
    n = 2 * cfg.window_size * 2  # exactly two batches
    pkts = rng.integers(0, 1 << 16, (n + 13, 2), dtype=np.uint32)
    path = tmp_path / "capture.pcl"
    PcapLite.write(path, pkts, compress=False)

    eng = TrafficEngine(cfg, policy="blocking", sinks=[StatsAccumulator()])
    rep = eng.run(str(path))
    assert rep.batches == 2  # trailing partial batch dropped
    assert rep.packets == n
    totals = eng.finalize()["stats"]
    assert int(totals["valid_packets"]) == n

    # batch 0 analytics match a direct build of the same packets
    half = pkts[: n // 2]
    A = matrix_build(jnp.asarray(half[:, 0]), jnp.asarray(half[:, 1]))
    assert int(totals["per_batch"][0]["unique_links"]) == int(A.nnz)


def test_iterable_source_and_report_overflow(rng):
    cfg = _cfg(windows_per_batch=2, cap_max_log2=6, anonymization="none")
    # all-unique coordinates => each 2-window merge overflows its 64-cap
    batch = np.arange(2 * 64 * 2, dtype=np.uint32).reshape(2, 64, 2)
    eng = TrafficEngine(cfg, policy="blocking")
    rep = eng.run(IterableSource(it=[batch, batch]))
    assert rep.batches == 2
    assert rep.merge_overflow == 2 * 64  # 128 unique into cap 64, twice


# -- stage graph validation -------------------------------------------------
def test_stage_graph_rejects_missing_dependency():
    with pytest.raises(ValueError, match="requires"):
        StageGraph(_cfg(), stages=("anonymize", "merge"))


def test_stage_graph_rejects_unknown_stage_and_output():
    with pytest.raises(ValueError, match="unknown stage"):
        StageGraph(_cfg(), stages=("anonymize", "nope"))
    with pytest.raises(ValueError, match="outputs"):
        StageGraph(_cfg(), stages=("anonymize", "build"),
                   outputs=("stats",))


def test_window_analytics_stage():
    cfg = _cfg(windows_per_batch=2)
    graph = StageGraph(cfg, stages=("build", "window_analytics"),
                       outputs=("window_stats",))
    batch = np.random.default_rng(0).integers(
        0, 1 << 16, (2, cfg.window_size, 2), dtype=np.uint32
    )
    out = graph(jnp.asarray(batch))
    assert out["window_stats"]["valid_packets"].shape == (2,)
    np.testing.assert_array_equal(
        np.asarray(out["window_stats"]["valid_packets"]),
        [cfg.window_size, cfg.window_size],
    )


# -- sinks ------------------------------------------------------------------
def test_top_k_sink_finds_planted_heavy_hitter():
    cfg = _cfg(windows_per_batch=2, anonymization="none")
    rng = np.random.default_rng(1)
    batch = rng.integers(100, 1 << 16, (2, 64, 2), dtype=np.uint32)
    batch[0, :40] = (5, 7)  # plant a dominant link
    batch[1, :25] = (5, 7)

    eng = TrafficEngine(cfg, sinks=[TopKHeavyHitters(k=4)])
    eng.run(IterableSource(it=[batch]))
    ranked = eng.finalize()["top_k"]
    assert ranked[0][0] == (5, 7)
    assert ranked[0][1] == 65


def test_matrix_retention_sink(rng):
    cfg = _cfg(windows_per_batch=2)
    eng = TrafficEngine(cfg, sinks=[MatrixRetention(max_keep=2)])
    eng.run("uniform", n_batches=3, seed=0)
    kept = eng.finalize()["matrices"]
    assert len(kept) == 2  # oldest evicted
    assert kept[-1].rows.shape[0] == cfg.level_capacity(1)


def test_sharded_rejects_matrix_sinks():
    with pytest.raises(ValueError, match="sharded"):
        TrafficEngine(_cfg(), policy="sharded", sinks=[MatrixRetention()])


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        TrafficEngine(_cfg(), policy="quantum")


# -- EngineReport edges: zero-batch streams, multi-cycle resume folding -----
def test_zero_batch_stream_report_is_zero_everywhere():
    """A stream that ends before its first batch must report clean zeros
    (no div-zero in the throughput property, a printable summary) for
    every canonical policy."""
    from repro.engine import canonical_policies

    for policy_name in sorted(canonical_policies()):
        eng = TrafficEngine(_cfg(), policy=policy_name,
                            sinks=[StatsAccumulator()])
        rep = eng.run("uniform", n_batches=0, seed=5)
        assert rep.batches == 0 and rep.packets == 0, policy_name
        assert rep.process_s == 0.0, policy_name
        assert rep.overlap_s == 0.0, policy_name
        assert rep.packets_per_second == 0.0, policy_name
        assert "0 packets" in rep.summary(), policy_name
        assert eng.finalize()["stats"] == {"batches": 0}


def test_zero_batch_daemon_stream_report_is_zero():
    """Same edge via the serve path: a daemon shut down before any ingest
    reports zeros and still writes no bogus throughput."""
    from repro.serve import AnalyticsDaemon

    daemon = AnalyticsDaemon(_cfg(), policy="blocking", queue_depth=2)
    daemon.bind("tcp://127.0.0.1:0")
    daemon.start()
    daemon.shutdown()
    rep = daemon.join()
    assert rep.batches == 0 and rep.packets == 0
    assert rep.packets_per_second == 0.0
    assert daemon.finalize()["stats"] == {"batches": 0}


def test_report_folds_exactly_across_three_kill_resume_cycles(tmp_path):
    """The resume chain's *logical* report: after N crash/resume cycles
    the final report's batch/packet totals are exact (no double counting),
    and every cycle's report keeps the async-policy time invariant
    process_s + overlap_s <= elapsed_s."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.engine import FaultPlan, FaultTolerance

    n_batches = 8
    mgr = CheckpointManager(tmp_path / "ckpt", keep=10)
    per_item = _cfg().window_size * _cfg().windows_per_batch

    reports = []
    cursors = []
    for crash_at in (2, 4, 6):  # three killed cycles...
        eng = TrafficEngine(_cfg(), policy="async_pipelined",
                            sinks=[StatsAccumulator()])
        with pytest.raises(RuntimeError, match="injected crash"):
            eng.run("uniform", n_batches=n_batches, seed=5,
                    fault_tolerance=FaultTolerance(
                        plan=FaultPlan.parse(f"crash@{crash_at}")),
                    checkpoint_every=1, checkpoint_manager=mgr,
                    resume=True)
        cursors.append(mgr.latest_step() or 0)

    # the chain makes progress (each cycle's crash lands deeper into the
    # stream than the last surviving checkpoint)
    assert cursors == sorted(cursors)
    assert cursors[-1] < n_batches

    # ...then one clean run to the end of the stream
    eng = TrafficEngine(_cfg(), policy="async_pipelined",
                        sinks=[StatsAccumulator()])
    rep = eng.run("uniform", n_batches=n_batches, seed=5,
                  checkpoint_every=1, checkpoint_manager=mgr, resume=True)
    reports.append(rep)
    res = eng.finalize()

    assert rep.resumed_from == cursors[-1]
    assert rep.batches == n_batches  # folded totals, not this cycle's
    assert rep.packets == n_batches * per_item
    assert res["stats"]["batches"] == n_batches
    # wall-clock sanity on the surviving report(s): exposed device wait
    # plus hidden in-flight time can never exceed the cycle's wall time
    for r in reports:
        assert r.process_s + r.overlap_s <= r.elapsed_s + 1e-9
        assert r.elapsed_s > 0.0
