"""launch.daemon CLI end-to-end: the CI ``daemon`` job's contract.

Start the daemon as a real subprocess, stream batches at it over TCP,
query the roll-up hierarchy, SIGTERM it, and assert the drain contract:
exit 0, a final checkpoint at the exact stream cursor, and checkpointed
stats bit-identical to a batch run over the same stream.
"""

import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.window import WindowConfig
from repro.engine import StatsAccumulator, TrafficEngine
from repro.engine.source import DeviceSyntheticSource
from repro.serve.client import DaemonClient, IngestClient

W, WINDOW = 4, 64
N_BATCHES = 6
SEED = 23

pytestmark = pytest.mark.slow  # subprocess + jax import per test


def _batches(n=N_BATCHES, seed=SEED):
    return list(DeviceSyntheticSource(
        kind="uniform", seed=seed, n_batches=n, windows_per_batch=W,
        window_size=WINDOW, placement="host"))


def _spawn(tmp_path: Path, *extra: str) -> subprocess.Popen:
    env = dict(os.environ)
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(root / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.launch.daemon",
        "--serve", "tcp://127.0.0.1:0",
        "--window-log2", "6", "--windows-per-batch", str(W),
        "--anonymization", "none", "--queue-depth", "4",
        *extra,
    ]
    return subprocess.Popen(cmd, env=env, cwd=str(root),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


def _await_address(proc: subprocess.Popen) -> str:
    # first stdout line is "serving on tcp://127.0.0.1:<port>" (flushed
    # before the signal handlers are installed)
    line = proc.stdout.readline()
    if not line.startswith("serving on "):
        out, err = proc.communicate(timeout=30)
        raise AssertionError(
            f"daemon failed to come up: {line!r}\n{out}\n{err}")
    return line.split("serving on ", 1)[1].strip()


def _finish(proc: subprocess.Popen, timeout=120.0):
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, err = proc.communicate()
        raise AssertionError(
            f"daemon did not exit after SIGTERM\n{out}\n{err}")
    return out, err


def test_daemon_cli_sigterm_drain_contract(tmp_path):
    ckpt_dir = tmp_path / "ckpts"
    proc = _spawn(tmp_path, "--rollup-levels", "3",
                  "--checkpoint-dir", str(ckpt_dir),
                  "--checkpoint-every", "2")
    try:
        address = _await_address(proc)
        with IngestClient(address) as ing, DaemonClient(address) as ctl:
            ing.send_stream(_batches())
            assert ing.end()["received"] == N_BATCHES
            ctl.wait_consumed(N_BATCHES, timeout=120.0)
            levels = ctl.query("levels")["levels"]
            assert levels[1][0]["span"] == 2
            status = ctl.status()
            assert status["consumed"] == N_BATCHES
        proc.send_signal(signal.SIGTERM)
        out, err = _finish(proc)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, f"exit {proc.returncode}\n{out}\n{err}"

    summary = json.loads(out.strip().splitlines()[-1])
    assert summary["batches"] == N_BATCHES
    assert summary["packets"] == N_BATCHES * W * WINDOW
    assert summary["checkpoints_written"] >= 1

    # final checkpoint at the exact stream cursor...
    mgr = CheckpointManager(ckpt_dir)
    assert mgr.latest_step() == N_BATCHES
    state, meta = mgr.restore(None)
    assert state["batches_done"] == N_BATCHES
    assert state["stream_pos"] == N_BATCHES
    assert state["packets_done"] == N_BATCHES * W * WINDOW

    # ...whose stats sink state is bit-identical to a batch run
    cfg = WindowConfig(window_log2=6, windows_per_batch=W,
                       anonymization="none")
    ref = StatsAccumulator()
    eng = TrafficEngine(cfg, policy="blocking", sinks=[ref])
    eng.run(DeviceSyntheticSource(
        kind="uniform", seed=SEED, n_batches=N_BATCHES,
        windows_per_batch=W, window_size=WINDOW, placement="host"))
    eng.finalize()
    want = ref.state_dict()
    got = state["sinks"]["stats"]
    assert got["overflow"] == want["overflow"]
    assert len(got["per_batch"]) == len(want["per_batch"])
    for a, b in zip(want["per_batch"], got["per_batch"]):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]), err_msg=f"stats:{k}")


def test_daemon_cli_rejects_resume_without_checkpoint_dir(tmp_path):
    proc = _spawn(tmp_path, "--resume")
    out, err = proc.communicate(timeout=60)
    assert proc.returncode == 2
    assert "--resume requires --checkpoint-dir" in err
