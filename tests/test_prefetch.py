"""BoundedPrefetcher lifecycle: cancellation, early close, and the
error-after-drain contract its docstring promises — the producer/consumer
primitive under ``double_buffered``, ``async_pipelined``, and
``sharded_pipelined`` must never leak its worker thread."""

import time

import pytest

from repro.engine import BoundedPrefetcher, WorkerDiedError, WorkerKilled


def test_early_consumer_exit_close_joins_worker():
    produced = []

    def gen():
        for i in range(1000):
            produced.append(i)
            yield i

    pf = BoundedPrefetcher(gen(), depth=2)
    got = []
    for x in pf:
        got.append(x)
        if len(got) == 3:
            break
    pf.close()
    assert got == [0, 1, 2]
    assert pf.closed
    assert not pf._thread.is_alive()  # worker joined, not leaked
    # backpressure bounded production: consumed + queue depth + in-hand
    assert len(produced) <= 3 + 2 + 2
    # iteration after close yields nothing (the queue is closed)
    assert list(pf) == []


def test_close_is_idempotent_and_safe_after_exhaustion():
    pf = BoundedPrefetcher(iter(range(3)), depth=2)
    assert list(pf) == [0, 1, 2]
    assert pf.closed  # exhaustion closes
    pf.close()
    pf.close()
    assert not pf._thread.is_alive()


def test_context_manager_closes_on_exit():
    def gen():
        while True:
            yield 0

    with BoundedPrefetcher(gen(), depth=2) as pf:
        assert next(pf) == 0
    assert pf.closed
    assert not pf._thread.is_alive()


def test_close_unblocks_worker_stuck_on_full_queue():
    # depth 1 and a never-consuming consumer: the worker is parked on a
    # full queue; close() must still join it promptly
    pf = BoundedPrefetcher(iter(range(100)), depth=1)
    time.sleep(0.05)  # let the worker fill the queue and block
    t0 = time.perf_counter()
    pf.close()
    assert time.perf_counter() - t0 < 2.0
    assert not pf._thread.is_alive()


def test_close_from_another_thread_unblocks_waiting_consumer():
    """A watchdog thread may close() while the consumer is parked on an
    empty queue; the consumer must wake and stop, not hang forever."""
    import threading

    release = threading.Event()

    def slow_gen():
        yield 0
        release.wait(60)  # the consumer will be parked waiting for item 2
        yield 1

    pf = BoundedPrefetcher(slow_gen(), depth=2)
    got, done = [], threading.Event()

    def consumer():
        for x in pf:
            got.append(x)
        done.set()

    t = threading.Thread(target=consumer, daemon=True)
    t.start()
    time.sleep(0.2)  # consumer got item 0 and is now blocked
    # watchdog thread: close() itself joins the (stalled) worker with a
    # bounded timeout, so it runs off the assertion path
    threading.Thread(target=pf.close, daemon=True).start()
    assert done.wait(timeout=2.0)
    assert got == [0]
    # un-stall the producer so the worker exits promptly (close() cannot
    # interrupt a generator blocked inside its own body) and join it —
    # otherwise the thread-leak fixture rightly flags the worker
    release.set()
    pf._thread.join(timeout=2.0)
    assert not pf._thread.is_alive()


def test_transform_error_reraises_after_drained_items():
    """Per the docstring: items produced before the failure are delivered,
    then the transform's exception surfaces in the consumer."""

    def bad(x):
        if x == 2:
            raise RuntimeError("device_put blew up")
        return x * 10

    pf = BoundedPrefetcher(iter(range(5)), depth=5, transform=bad)
    out = []
    with pytest.raises(RuntimeError, match="device_put blew up"):
        for x in pf:
            out.append(x)
    assert out == [0, 10]
    assert not pf._thread.is_alive()


def test_source_error_reraises_after_drained_items():
    def dying():
        yield 1
        yield 2
        raise OSError("pcap truncated")

    pf = BoundedPrefetcher(dying(), depth=4)
    out = []
    with pytest.raises(OSError, match="pcap truncated"):
        for x in pf:
            out.append(x)
    assert out == [1, 2]
    assert not pf._thread.is_alive()


def test_produce_time_accounting():
    def slow(x):
        time.sleep(0.01)
        return x

    pf = BoundedPrefetcher(iter(range(3)), depth=2, transform=slow)
    assert list(pf) == [0, 1, 2]
    assert pf.produce_s >= 0.03


# -- multi-worker producers -------------------------------------------------


def test_workers_deliver_in_source_order_under_reordering():
    """4 workers with inverted per-item latency: late items finish their
    transforms *first*, yet the consumer must still see source order (the
    reorder buffer holds completed items until their turn)."""
    n = 12

    def jitter(x):
        time.sleep((n - x) * 0.004)  # item 0 is the slowest
        return x * 10

    pf = BoundedPrefetcher(iter(range(n)), depth=8, transform=jitter,
                           workers=4)
    assert list(pf) == [x * 10 for x in range(n)]
    for t in pf._threads:
        assert not t.is_alive()


def test_workers_error_delivers_prefix_then_raises():
    """With reordering workers, an item failing mid-stream must still let
    everything sequenced *before* it through, then raise — later items,
    even if already transformed, are discarded."""

    def bad(x):
        if x == 3:
            raise ValueError("boom at 3")
        time.sleep(0.002 * (8 - x))
        return x

    pf = BoundedPrefetcher(iter(range(8)), depth=8, transform=bad,
                           workers=3)
    out = []
    with pytest.raises(ValueError, match="boom at 3"):
        for x in pf:
            out.append(x)
    assert out == [0, 1, 2]
    for t in pf._threads:
        assert not t.is_alive()


def test_workers_validation():
    with pytest.raises(ValueError, match="workers"):
        BoundedPrefetcher(iter(range(3)), workers=0)


# -- close(): join-timeout warning + condition-driven (no-polling) wakeups --


def test_close_warns_by_name_when_worker_cannot_join():
    """A source wedged in foreign code can defeat close()'s join; that must
    be a RuntimeWarning naming the stuck thread, never a silent leak."""
    import threading

    release = threading.Event()

    def wedged():
        yield 0
        release.wait(60)  # blocked where close() cannot interrupt
        yield 1

    pf = BoundedPrefetcher(wedged(), depth=2)
    assert next(pf) == 0
    time.sleep(0.05)  # let the worker park inside the source
    with pytest.warns(RuntimeWarning, match="repro-prefetch-worker-0"):
        pf.close(timeout=0.1)
    assert pf.closed
    # un-wedge and reap the worker so the thread-leak fixture stays green
    release.set()
    pf._thread.join(timeout=2.0)
    assert not pf._thread.is_alive()


def test_close_while_parked_returns_promptly():
    """Cancellation is condition-driven: a worker parked on a full buffer
    wakes on notify, not on a poll tick — close() latency is bounded by
    the wakeup, nowhere near any polling period."""
    pf = BoundedPrefetcher(iter(range(100)), depth=1)
    time.sleep(0.05)  # worker fills the buffer and parks on the bound
    t0 = time.perf_counter()
    pf.close()
    assert time.perf_counter() - t0 < 0.25
    assert not pf._thread.is_alive()


def test_close_wakes_parked_consumer_promptly():
    import threading

    release = threading.Event()

    def slow_gen():
        yield 0
        release.wait(60)
        yield 1

    pf = BoundedPrefetcher(slow_gen(), depth=2)
    assert next(pf) == 0
    woke = threading.Event()

    def consumer():
        for _ in pf:
            pass
        woke.set()

    t = threading.Thread(target=consumer, daemon=True)
    t.start()
    time.sleep(0.1)  # consumer is parked on the empty buffer
    t0 = time.perf_counter()
    threading.Thread(target=pf.close, daemon=True).start()
    assert woke.wait(timeout=0.5)
    assert time.perf_counter() - t0 < 0.5
    release.set()
    pf._thread.join(timeout=2.0)
    assert not pf._thread.is_alive()


# -- produce_s: locked snapshot, in-flight + error-path accounting ----------


def test_produce_s_snapshot_includes_in_progress_transform():
    import threading

    entered = threading.Event()
    release = threading.Event()

    def gated(x):
        if x == 1:
            entered.set()
            release.wait(10)
        return x

    pf = BoundedPrefetcher(iter(range(3)), depth=2, transform=gated)
    assert next(pf) == 0
    assert entered.wait(timeout=2.0)
    time.sleep(0.05)  # transform of item 1 is mid-flight
    assert pf.produce_s >= 0.05  # snapshot sees the in-progress transform
    release.set()
    assert list(pf) == [1, 2]
    assert not pf._thread.is_alive()


def test_produce_s_keeps_failed_transform_time():
    """A transform that dies mid-stream still spent IO time; the error
    path must bank it, not drop it with the traceback."""

    def bad(x):
        time.sleep(0.04)
        if x == 1:
            raise RuntimeError("mid-stream")
        return x

    pf = BoundedPrefetcher(iter(range(3)), depth=2, transform=bad)
    with pytest.raises(RuntimeError, match="mid-stream"):
        list(pf)
    assert pf.produce_s >= 0.08  # both the good and the failed transform
    assert not pf._thread.is_alive()


# -- worker death: last rites, heartbeat eviction ---------------------------


def test_worker_death_delivers_prefix_then_worker_died_error():
    """A worker unwound by ``WorkerKilled`` (the injected-death path) holds
    a reserved sequence number; last rites must record ``WorkerDiedError``
    at that seq so the consumer drains the prefix and then raises instead
    of parking forever on the gap."""

    def lethal(x):
        if x == 3:
            raise WorkerKilled("chaos")
        time.sleep(0.002 * (8 - x))
        return x

    pf = BoundedPrefetcher(iter(range(8)), depth=8, transform=lethal,
                           workers=2)
    out = []
    with pytest.raises(WorkerDiedError, match="died while producing item"):
        for x in pf:
            out.append(x)
    assert out == [0, 1, 2]
    # the fallen worker's heartbeat host is marked dead, and health()
    # reports it as an eviction before any straggle heuristics apply
    fallen = [h for h in pf.monitor.hosts.values() if not h.alive]
    assert len(fallen) == 1
    decision = pf.health()
    assert decision.action == "evict"
    assert decision.hosts == (fallen[0].host_id,)
    for t in pf._threads:
        t.join(timeout=2.0)
        assert not t.is_alive()


def test_worker_death_in_source_pull_surfaces_too():
    """``WorkerKilled`` raised inside the *source* (not the transform)
    takes the same last-rites path: the reserved seq is recorded."""

    def dying_source():
        yield 0
        yield 1
        raise WorkerKilled("source-side chaos")

    pf = BoundedPrefetcher(dying_source(), depth=2, workers=2)
    out = []
    with pytest.raises(WorkerDiedError, match="died while producing item 2"):
        for x in pf:
            out.append(x)
    assert out == [0, 1]
    assert pf.health().action == "evict"
    for t in pf._threads:
        t.join(timeout=2.0)
        assert not t.is_alive()


def test_surviving_workers_record_heartbeats():
    """Every delivered item beats the delivering worker's heartbeat host:
    after a clean run the monitor has seen every sequence number and
    health() has no complaints."""
    pf = BoundedPrefetcher(iter(range(10)), depth=4,
                           transform=lambda x: x, workers=2)
    assert list(pf) == list(range(10))
    assert sum(len(h.step_times) for h in pf.monitor.hosts.values()) == 10
    assert max(h.last_step for h in pf.monitor.hosts.values()) == 9
    assert all(h.alive for h in pf.monitor.hosts.values())
    assert pf.health().action == "proceed"
    for t in pf._threads:
        assert not t.is_alive()
