"""matrix_build: the GrB_Matrix_build reproduction, against numpy oracles
and algebraic properties (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import matrix_build, types
from repro.core.build import build_window, lex_sort, vector_build


def dense_ref(src, dst, n, vals=None):
    ref = np.zeros((n, n), np.int64)
    np.add.at(ref, (src.astype(np.int64), dst.astype(np.int64)),
              1 if vals is None else vals)
    return ref


def as_dense(A, n):
    r, c, v = A.entries()
    out = np.zeros((n, n), np.int64)
    out[r.astype(np.int64), c.astype(np.int64)] = v
    return out


@pytest.mark.parametrize("n,ids", [(64, 8), (1024, 50), (4096, 3000)])
def test_build_matches_numpy(rng, n, ids):
    src = rng.integers(0, ids, n).astype(np.uint32)
    dst = rng.integers(0, ids, n).astype(np.uint32)
    A = jax.jit(lambda r, c: matrix_build(r, c, nrows=ids, ncols=ids))(
        src, dst
    )
    assert np.array_equal(as_dense(A, ids), dense_ref(src, dst, ids))
    assert int(A.nnz) == (dense_ref(src, dst, ids) > 0).sum()


def test_build_full_address_space(rng):
    """Coordinates across the whole 2^32 space, including 0xFFFFFFFF."""
    src = rng.integers(0, 1 << 32, 500, dtype=np.uint32)
    dst = rng.integers(0, 1 << 32, 500, dtype=np.uint32)
    src[:3] = 0xFFFFFFFF  # broadcast addresses are legal traffic
    dst[:3] = 0xFFFFFFFF
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst))
    r, c, v = A.entries()
    # exact multiset equality with numpy unique
    pairs = np.stack([src, dst], 1)
    uniq, counts = np.unique(pairs, axis=0, return_counts=True)
    assert int(A.nnz) == len(uniq)
    got = {(int(a), int(b)): int(x) for a, b, x in zip(r, c, v)}
    want = {(int(a), int(b)): int(k) for (a, b), k in zip(uniq, counts)}
    assert got == want


def test_build_with_n_valid(rng):
    src = rng.integers(0, 50, 256).astype(np.uint32)
    dst = rng.integers(0, 50, 256).astype(np.uint32)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=64, ncols=64,
                     n_valid=100)
    assert np.array_equal(
        as_dense(A, 64), dense_ref(src[:100], dst[:100], 64)
    )


def test_build_dup_monoids(rng):
    src = rng.integers(0, 10, 200).astype(np.uint32)
    dst = rng.integers(0, 10, 200).astype(np.uint32)
    vals = rng.integers(1, 100, 200).astype(np.int32)
    for monoid, np_op in [(types.PLUS_MONOID, np.add),
                          (types.MIN_MONOID, np.minimum),
                          (types.MAX_MONOID, np.maximum)]:
        A = matrix_build(jnp.asarray(src), jnp.asarray(dst),
                         jnp.asarray(vals), nrows=10, ncols=10, dup=monoid)
        ident = {"plus": 0, "min": np.iinfo(np.int32).max,
                 "max": np.iinfo(np.int32).min}[monoid.name]
        ref = np.full((10, 10), ident, np.int64)
        np_op.at(ref, (src.astype(int), dst.astype(int)), vals)
        if monoid.name == "plus":
            ref[ref == ident] = 0
        mask = dense_ref(src, dst, 10) > 0
        got = as_dense(A, 10)
        assert np.array_equal(got[mask], ref[mask]), monoid.name


@given(
    st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)),
             min_size=1, max_size=200)
)
def test_build_property_counts(pairs):
    """nnz == #distinct pairs; sum == #pairs; order sorted; no dups."""
    arr = np.array(pairs, np.uint32)
    A = matrix_build(jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
                     nrows=32, ncols=32)
    r, c, v = A.entries()
    assert int(A.nnz) == len({tuple(p) for p in pairs})
    assert v.sum() == len(pairs)
    keys = list(zip(r.tolist(), c.tolist()))
    assert keys == sorted(keys)
    assert len(set(keys)) == len(keys)


def test_lex_sort_stability_and_validity(rng):
    """Caller contract: invalid keys are forced to SENTINEL first; the
    valid= tiebreak then guarantees real max-key entries (255.255.255.255)
    still precede padding, so the leading-nnz invariant holds."""
    from repro.core.hypersparse import SENTINEL

    rows = rng.integers(0, 5, 64).astype(np.uint32)
    cols = rng.integers(0, 5, 64).astype(np.uint32)
    valid = rng.random(64) < 0.5
    # include a real broadcast-address entry among the valid ones
    rows[np.argmax(valid)] = 0xFFFFFFFF
    cols[np.argmax(valid)] = 0xFFFFFFFF
    forced_r = np.where(valid, rows, np.uint32(SENTINEL))
    forced_c = np.where(valid, cols, np.uint32(SENTINEL))
    payload = np.arange(64).astype(np.int32)
    r, c, p = lex_sort(jnp.asarray(forced_r), jnp.asarray(forced_c),
                       jnp.asarray(payload), valid=jnp.asarray(valid))
    r, c, p = np.asarray(r), np.asarray(c), np.asarray(p)
    nv = valid.sum()
    # all valid entries first (their original keys), sorted lexicographically
    assert valid[p[:nv]].all() and not valid[p[nv:]].any()
    got = list(zip(r[:nv].tolist(), c[:nv].tolist()))
    want = sorted(zip(rows[valid].tolist(), cols[valid].tolist()))
    assert got == want


def test_lex_sort_valid_matches_three_argsort_reference(rng):
    """Regression pin for the fused valid= sort: the single variadic
    3-key sort must reproduce the former 3-argsort pre-pass permutation
    exactly — both are stable, so the output order is uniquely determined:
    (row, col) ascending, valid-before-invalid within equal keys, original
    order within equal (key, validity).  Duplicate keys carry distinct
    payloads so any stability break is visible."""
    for seed in (0, 1, 2):
        r = np.random.default_rng(seed)
        rows = r.integers(0, 3, 128).astype(np.uint32)
        cols = r.integers(0, 3, 128).astype(np.uint32)
        valid = r.random(128) < 0.6
        payload = np.arange(128, dtype=np.int32)  # original position

        def ref_three_argsort(rows, cols, payload, valid):
            perm0 = np.argsort(~valid, kind="stable")
            rows, cols = rows[perm0], cols[perm0]
            payload = payload[perm0]
            perm1 = np.argsort(cols, kind="stable")
            perm2 = np.argsort(rows[perm1], kind="stable")
            perm = perm1[perm2]
            return rows[perm], cols[perm], payload[perm]

        got = lex_sort(jnp.asarray(rows), jnp.asarray(cols),
                       jnp.asarray(payload), valid=jnp.asarray(valid))
        want = ref_three_argsort(rows, cols, payload, valid)
        for g, w, name in zip(got, want, ("rows", "cols", "payload")):
            np.testing.assert_array_equal(np.asarray(g), w,
                                          err_msg=f"seed={seed}:{name}")


def test_vector_build(rng):
    idx = rng.integers(0, 100, 300).astype(np.uint32)
    vals = rng.integers(1, 5, 300).astype(np.int32)
    v = vector_build(jnp.asarray(idx), jnp.asarray(vals), length=100)
    ref = np.zeros(100, np.int64)
    np.add.at(ref, idx.astype(int), vals)
    assert np.array_equal(np.asarray(v.to_dense()), ref)


def test_build_window_shape(rng):
    pkts = rng.integers(0, 1 << 32, (1024, 2), dtype=np.uint32)
    A = build_window(jnp.asarray(pkts))
    assert A.capacity == 1024
    assert int(A.vals.sum()) == 1024


def test_count_fast_path_equals_general(rng):
    """The counting build (no value payload) == the general build with
    explicit ones, including the broadcast-address corner."""
    src = rng.integers(0, 1 << 32, 2048, dtype=np.uint32)
    dst = rng.integers(0, 1 << 32, 2048, dtype=np.uint32)
    src[:5] = 0xFFFFFFFF
    dst[:5] = 0xFFFFFFFF
    fast = matrix_build(jnp.asarray(src), jnp.asarray(dst),
                        count_fast_path=True, n_valid=2000)
    slow = matrix_build(jnp.asarray(src), jnp.asarray(dst),
                        count_fast_path=False, n_valid=2000)
    assert int(fast.nnz) == int(slow.nnz)
    np.testing.assert_array_equal(np.asarray(fast.rows),
                                  np.asarray(slow.rows))
    np.testing.assert_array_equal(np.asarray(fast.cols),
                                  np.asarray(slow.cols))
    np.testing.assert_array_equal(np.asarray(fast.vals),
                                  np.asarray(slow.vals))
