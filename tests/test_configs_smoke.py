"""Per-architecture smoke tests (the assignment's reduced-config
requirement): one forward/train step on CPU, asserting shapes + no NaNs —
for every assigned arch + the paper's own workload."""

import jax
import pytest

from repro import configs


@pytest.mark.parametrize("arch_id", sorted(configs.ARCHS))
def test_arch_smoke(arch_id):
    case = configs.get(arch_id).smoke()
    if case.state is None:
        out = jax.jit(lambda b: case.fn(None, b))(case.batch)
    else:
        out = jax.jit(case.fn)(case.state, case.batch)
    case.check(jax.block_until_ready(out))


def test_registry_covers_assignment():
    expected = {
        "llama3.2-1b", "granite-3-8b", "qwen1.5-0.5b", "qwen2-moe-a2.7b",
        "phi3.5-moe-42b-a6.6b", "gat-cora", "gcn-cora", "egnn", "pna",
        "two-tower-retrieval",
    }
    assert expected <= set(configs.ARCHS)
    # 40 assigned cells + paper cells
    cells = configs.all_cells()
    assigned = [(a, s) for a, s in cells if a != "traffic-matrix"]
    assert len(assigned) == 40


def test_exact_dims_match_assignment():
    from repro.configs import (granite_3_8b, llama3_2_1b, phi3_5_moe,
                               qwen1_5_0_5b, qwen2_moe_a2_7b, two_tower)

    c = llama3_2_1b.model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (16, 2048, 32, 8, 8192, 128256)
    c = granite_3_8b.model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 12800, 49155)
    c = qwen1_5_0_5b.model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (24, 1024, 16, 16, 2816, 151936,
                                          True)
    c = qwen2_moe_a2_7b.model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.moe.n_experts, c.moe.top_k,
            c.moe.d_ff_expert) == (24, 2048, 16, 60, 4, 1408)
    c = phi3_5_moe.model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.moe.n_experts,
            c.moe.top_k, c.vocab_size) == (32, 4096, 32, 8, 16, 2, 32064)
    c = two_tower.model_config()
    assert (c.embed_dim, c.tower_mlp) == (256, (1024, 512, 256))


def test_lm_flops_accounting():
    """6*N*D for dense; 6*N_active*D for MoE (active << total)."""
    from repro.configs import phi3_5_moe

    cfg = phi3_5_moe.model_config()
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 35e9 < total < 50e9          # ~42B
    assert 5e9 < active < 9e9           # ~6.6B
    assert active < total / 4
