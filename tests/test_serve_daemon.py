"""Always-on analytics daemon: protocol, roll-ups, exporter, equivalence.

The tentpole invariant: for the same stream, daemon-mode stats and
retained matrices are bit-identical to a batch run — over every
canonical policy.  Plus the serve building blocks: frame protocol
round-trips, the ingest stream's backpressure/close semantics, roll-up
exactness against explicit pairwise merges, exporter flagging and its
crash/resume-exact file journal, and daemon checkpoint/resume with a
replaying client.
"""

import io as _io

import numpy as np
import pytest

from repro.checkpoint.framelog import FrameLog, pack_frame, read_frame
from repro.checkpoint.manager import CheckpointManager
from repro.core import ops, types
from repro.core.window import WindowConfig
from repro.engine import (
    MatrixRetention,
    ShardedPolicy,
    StatsAccumulator,
    TrafficEngine,
    canonical_policies,
)
from repro.engine.source import DeviceSyntheticSource
from repro.serve import (
    AnalyticsDaemon,
    DaemonClient,
    ExporterSink,
    IngestClient,
    RollupSink,
    StreamQueueSource,
    collect_exports,
)
from repro.serve import protocol
from repro.serve.client import DaemonRequestError

POLICY_NAMES = sorted(canonical_policies())
N_BATCHES = 6
SEED = 23
W, WINDOW = 4, 64


def _is_sharded(policy_name: str) -> bool:
    return issubclass(canonical_policies()[policy_name], ShardedPolicy)


def _cfg():
    return WindowConfig(window_log2=6, windows_per_batch=W,
                        anonymization="none")


def _batches(n=N_BATCHES, seed=SEED):
    return list(DeviceSyntheticSource(
        kind="uniform", seed=seed, n_batches=n, windows_per_batch=W,
        window_size=WINDOW, placement="host"))


def _source(n=N_BATCHES, seed=SEED):
    return DeviceSyntheticSource(kind="uniform", seed=seed, n_batches=n,
                                 windows_per_batch=W, window_size=WINDOW,
                                 placement="host")


def _assert_stats_identical(ref, got, label=""):
    assert ref.keys() == got.keys()
    for k in ref:
        if k == "per_batch":
            assert len(ref[k]) == len(got[k]), label
            for a, b in zip(ref[k], got[k]):
                for kk in a:
                    np.testing.assert_array_equal(
                        np.asarray(a[kk]), np.asarray(b[kk]),
                        err_msg=f"{label}:per_batch:{kk}")
            continue
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(got[k]),
                                      err_msg=f"{label}:{k}")


def _assert_matrices_identical(ref, got):
    assert len(ref) == len(got)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.cols), np.asarray(b.cols))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
        assert int(a.nnz) == int(b.nnz)


# -- protocol / framing ------------------------------------------------------

def test_frame_round_trip_bytes():
    tree = {"batch": np.arange(12, dtype=np.uint32).reshape(3, 4),
            "tag": "x", "n": 7, "nested": [1.5, (True, None)]}
    blob = pack_frame(protocol.MSG_INGEST, tree)
    kind, got = read_frame(_io.BytesIO(blob).read)
    assert kind == protocol.MSG_INGEST
    np.testing.assert_array_equal(got["batch"], tree["batch"])
    assert got["tag"] == "x" and got["n"] == 7
    assert got["nested"] == [1.5, (True, None)]
    # clean EOF -> None; truncated frame -> error
    assert read_frame(_io.BytesIO(b"").read) is None
    with pytest.raises(EOFError):
        read_frame(_io.BytesIO(blob[:-3]).read)


def test_frame_log_append_cursor_truncate(tmp_path):
    path = tmp_path / "log.rpfr"
    log = FrameLog(path)
    pos1 = log.append(1, {"i": 0})
    pos2 = log.append(2, {"i": 1})
    assert log.tell() == pos2 > pos1
    log.append(3, {"i": 2})
    log.truncate_to(pos2)  # drop the third frame
    assert [k for k, _ in FrameLog.read_all(path)] == [1, 2]
    # re-append after truncation is bit-stable
    log.append(3, {"i": 2})
    log.close()
    assert [t["i"] for _, t in FrameLog.read_all(path)] == [0, 1, 2]
    with pytest.raises(ValueError, match="shorter than"):
        log.truncate_to(10**9)


def test_parse_address_forms():
    assert protocol.parse_address("tcp://127.0.0.1:9000") == \
        ("tcp", ("127.0.0.1", 9000))
    assert protocol.parse_address("unix:///tmp/s.sock") == \
        ("unix", "/tmp/s.sock")
    assert protocol.parse_address("/tmp/s.sock") == ("unix", "/tmp/s.sock")
    with pytest.raises(ValueError):
        protocol.parse_address("tcp://nohost")


# -- StreamQueueSource -------------------------------------------------------

def test_stream_queue_validates_and_orders():
    s = StreamQueueSource(window_size=WINDOW, windows_per_batch=W,
                          maxsize=8)
    batches = _batches(3)
    for b in batches:
        s.put(b)
    flat = batches[0].reshape(-1, 2)
    s.put(flat)  # flat form reshapes
    with pytest.raises(ValueError, match="dtype"):
        s.put(batches[0].astype(np.int64))
    with pytest.raises(ValueError, match="shape"):
        s.put(batches[0][:, :-1])
    s.close()
    got = list(s)
    assert len(got) == 4
    np.testing.assert_array_equal(got[0], batches[0])
    np.testing.assert_array_equal(got[3], batches[0])
    with pytest.raises(RuntimeError, match="closed"):
        s.put(batches[0])
    assert s.accepted == 4


def test_stream_queue_put_unblocks_on_close():
    s = StreamQueueSource(window_size=WINDOW, windows_per_batch=W,
                          maxsize=1)
    b = _batches(1)[0]
    s.put(b)  # queue now full
    import threading

    errs = []

    def blocked_put():
        try:
            s.put(b)
        except RuntimeError as e:
            errs.append(e)

    t = threading.Thread(target=blocked_put)
    t.start()
    s.close()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert errs and "closed" in str(errs[0])


# -- daemon equivalence (the tentpole invariant) -----------------------------

@pytest.mark.parametrize("policy_name", POLICY_NAMES)
def test_daemon_equivalent_to_batch_run(policy_name):
    """Socket-ingested daemon run == batch run, bit-identically, for
    every canonical policy (stats always; retained matrices where the
    policy can feed matrix sinks)."""
    sharded = _is_sharded(policy_name)

    ref_sinks = [StatsAccumulator()]
    if not sharded:
        ref_sinks.append(MatrixRetention(max_keep=8))
    ref_eng = TrafficEngine(_cfg(), policy=policy_name, sinks=ref_sinks)
    ref_eng.run(_source(), seed=SEED)
    ref = ref_eng.finalize()

    sinks = [StatsAccumulator()]
    if not sharded:
        sinks.append(MatrixRetention(max_keep=8))
    daemon = AnalyticsDaemon(_cfg(), policy=policy_name, sinks=sinks,
                             queue_depth=3)
    addr = daemon.bind("tcp://127.0.0.1:0")
    daemon.start()
    with IngestClient(addr) as ing, DaemonClient(addr) as ctl:
        ing.send_stream(_batches())
        assert ing.end()["received"] == N_BATCHES
        # no wait_consumed here: pipelined policies retire their last
        # ring-depth batches only at stream end; shutdown's drain
        # guarantees everything acked above is processed
        ctl.shutdown()
    report = daemon.join()
    got = daemon.finalize()

    assert report.batches == N_BATCHES
    assert report.packets == N_BATCHES * W * WINDOW
    _assert_stats_identical(ref["stats"], got["stats"], policy_name)
    if not sharded:
        _assert_matrices_identical(ref["matrices"], got["matrices"])


def test_daemon_many_clients_interleave_queries():
    """Concurrent query clients during ingest all get well-formed answers
    (the load-test shape, minus the timing)."""
    import threading

    daemon = AnalyticsDaemon(_cfg(), policy="blocking", rollup_levels=3,
                             queue_depth=3)
    addr = daemon.bind("tcp://127.0.0.1:0")
    daemon.start()
    stop = threading.Event()
    failures = []

    def worker():
        try:
            with DaemonClient(addr) as c:
                while not stop.is_set():
                    st = c.status()
                    assert st["accepted"] >= st["consumed"] >= 0
        except Exception as e:  # noqa: BLE001 - reported via failures
            failures.append(e)

    workers = [threading.Thread(target=worker) for _ in range(4)]
    for t in workers:
        t.start()
    with IngestClient(addr) as ing, DaemonClient(addr) as ctl:
        ing.send_stream(_batches())
        ing.end()
        ctl.wait_consumed(N_BATCHES)
        top = ctl.query("top_links", k=5, level=1)
        assert top["span"] == 2 and len(top["counts"]) <= 5
        stop.set()
        for t in workers:  # quiesce before shutdown closes connections
            t.join(timeout=10.0)
        ctl.shutdown()
    daemon.join()
    daemon.finalize()
    assert not failures
    assert all(not t.is_alive() for t in workers)


def test_daemon_rejects_bad_batches_and_unknown_queries():
    daemon = AnalyticsDaemon(_cfg(), policy="blocking", queue_depth=3)
    addr = daemon.bind("tcp://127.0.0.1:0")
    daemon.start()
    with IngestClient(addr) as ing, DaemonClient(addr) as ctl:
        ing.send_batch(np.zeros((2, 2), np.uint32))  # wrong shape
        ing.sent = 1
        with pytest.raises(DaemonRequestError):
            ing.end()
        with pytest.raises(DaemonRequestError, match="unknown query"):
            ctl.query("nope")
        with pytest.raises(DaemonRequestError, match="rollup_levels"):
            ctl.query("top_links")
        ctl.shutdown()
    daemon.join()
    daemon.finalize()


# -- roll-up hierarchy -------------------------------------------------------

def test_rollup_aggregates_are_exact_pairwise_merges():
    """A level-l aggregate is bit-identical to explicitly folding its
    2^l batch matrices with ewise_add — exactness by associativity."""
    cfg = _cfg()
    retention = MatrixRetention(max_keep=8)
    rollup = RollupSink(cfg, levels=3, keep_per_level=8)
    eng = TrafficEngine(cfg, policy="blocking", sinks=[retention, rollup])
    eng.run(_source(), seed=SEED)

    mats = retention.matrices
    lvl2 = rollup.levels_summary()["levels"][2]
    assert lvl2 == [{"start": 0, "span": 4,
                     "nnz": lvl2[0]["nnz"]}]
    agg = rollup._completed[2][0]["matrix"]

    expect = mats[0]
    for m in mats[1:4]:
        expect, ovf = ops.ewise_add(
            expect, m, types.PLUS,
            out_capacity=int(np.asarray(agg.rows).shape[0]))
        assert int(np.asarray(ovf)) == 0
    np.testing.assert_array_equal(np.asarray(agg.rows),
                                  np.asarray(expect.rows))
    np.testing.assert_array_equal(np.asarray(agg.cols),
                                  np.asarray(expect.cols))
    np.testing.assert_array_equal(np.asarray(agg.vals),
                                  np.asarray(expect.vals))
    assert int(np.asarray(agg.nnz)) == int(np.asarray(expect.nnz))
    eng.finalize()


def test_rollup_queries_and_diff():
    cfg = _cfg()
    rollup = RollupSink(cfg, levels=2, keep_per_level=4)
    eng = TrafficEngine(cfg, policy="blocking", sinks=[rollup])
    eng.run(_source(), seed=SEED)

    status = rollup.status()
    assert status["batches"] == N_BATCHES
    top = rollup.top_links(5, level=0, index=-1)
    assert len(top["counts"]) <= 5 and (top["counts"] > 0).all()
    talkers = rollup.top_talkers(5, level=0, index=-1)
    assert (talkers["counts"] > 0).all()
    hist = rollup.fanout(level=0, index=-1)["hist"]
    assert hist.sum() > 0
    # diff of an aggregate with itself is empty
    d = rollup.diff(level=0, index_a=-1, index_b=-1)
    assert d["nnz"] == 0
    # diff of different batches has signed deltas, zero entries dropped
    d = rollup.diff(level=0, index_a=-1, index_b=0)
    assert d["nnz"] > 0
    assert (np.asarray(d["vals"]) != 0).all()
    eng.finalize()


def test_rollup_state_round_trip():
    cfg = _cfg()
    a = RollupSink(cfg, levels=3, keep_per_level=4)
    eng = TrafficEngine(cfg, policy="blocking", sinks=[a])
    eng.run(_source(), seed=SEED)
    b = RollupSink(cfg, levels=3, keep_per_level=4)
    b.load_state_dict(a.state_dict())
    assert b.status() == a.status()
    assert b.levels_summary() == a.levels_summary()
    for lvl in range(3):
        if a._completed[lvl]:
            np.testing.assert_array_equal(
                np.asarray(a._completed[lvl][-1]["matrix"].vals),
                np.asarray(b._completed[lvl][-1]["matrix"].vals))
    eng.finalize()


# -- ExporterSink ------------------------------------------------------------

def _planted_batches():
    """Benign uniform batches, then one with a scan burst (single source
    hitting many destinations) that must flag under the z-score rule."""
    batches = _batches(6, seed=7)
    hot = batches[-1].copy()
    hot[0, :, 0] = 77                      # one source...
    hot[0, :, 1] = np.arange(WINDOW)       # ...sweeping WINDOW destinations
    batches[-1] = hot
    return batches


def test_exporter_flags_planted_scan_to_file(tmp_path):
    dest = tmp_path / "flags.rpfr"
    exporter = ExporterSink(str(dest), rule="zscore", threshold=3.0,
                            min_windows=4)
    eng = TrafficEngine(_cfg(), policy="blocking",
                        sinks=[StatsAccumulator(), exporter])
    from repro.engine import IterableSource

    eng.run(IterableSource(it=_planted_batches()))
    res = eng.finalize()["exporter"]
    assert res["exported"] >= 1
    records = collect_exports(dest)
    assert len(records) == res["exported"]
    rec = records[-1]
    assert rec["batch"] == 5 and 0 in rec["windows"]
    assert max(rec["scores"]) >= 3.0
    assert rec["matrix"]["nrows"] > 0


def test_exporter_benign_stream_exports_nothing(tmp_path):
    dest = tmp_path / "flags.rpfr"
    exporter = ExporterSink(str(dest), rule="zscore", threshold=4.0,
                            min_windows=4)
    eng = TrafficEngine(_cfg(), policy="blocking", sinks=[exporter])
    eng.run(_source(), seed=SEED)
    assert eng.finalize()["exporter"]["exported"] == 0
    assert collect_exports(dest) == []


def test_exporter_socket_destination(tmp_path):
    """Exports stream as MSG_EXPORT frames to a socket receiver."""
    import socket
    import threading

    from repro.checkpoint.framelog import SocketFrameIO

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    host, port = srv.getsockname()
    received = []

    def receiver():
        conn, _ = srv.accept()
        rio = SocketFrameIO(conn)
        while True:
            frame = rio.recv()
            if frame is None:
                break
            received.append(frame)
        rio.close()

    t = threading.Thread(target=receiver)
    t.start()
    exporter = ExporterSink(f"tcp://{host}:{port}", rule="zscore",
                            threshold=3.0, min_windows=4)
    eng = TrafficEngine(_cfg(), policy="blocking", sinks=[exporter])
    from repro.engine import IterableSource

    eng.run(IterableSource(it=_planted_batches()))
    res = eng.finalize()["exporter"]
    t.join(timeout=5.0)
    srv.close()
    assert len(received) == res["exported"] >= 1
    assert all(kind == protocol.MSG_EXPORT for kind, _ in received)


def test_exporter_resume_does_not_duplicate_file_records(tmp_path):
    """Crash after records were journaled past the checkpoint; resume must
    truncate to the cursor and re-append bit-identically."""
    from repro.engine import FaultPlan, FaultTolerance, IterableSource

    dest = tmp_path / "flags.rpfr"
    mgr = CheckpointManager(tmp_path / "ckpt")

    def build():
        exporter = ExporterSink(str(dest), rule="count", threshold=1,
                                keep_matrix=False)
        eng = TrafficEngine(_cfg(), policy="blocking",
                            sinks=[StatsAccumulator(), exporter])
        return eng

    # every batch exports under rule=count threshold=1; crash at stream
    # batch 4 (after the checkpoint at batch 2)
    eng = build()
    ft = FaultTolerance(plan=FaultPlan.parse("crash@4"))
    with pytest.raises(RuntimeError, match="injected crash"):
        eng.run(IterableSource(it=_batches(),
                           packets_per_item=W * WINDOW),
            fault_tolerance=ft,
                checkpoint_every=2, checkpoint_manager=mgr)
    journal_after_crash = collect_exports(dest)
    assert len(journal_after_crash) == 4  # batches 0..3 exported pre-crash

    eng2 = build()
    eng2.run(IterableSource(it=_batches(),
                            packets_per_item=W * WINDOW),
             checkpoint_every=2,
             checkpoint_manager=mgr, resume=True)
    eng2.finalize()
    records = collect_exports(dest)
    assert [r["batch"] for r in records] == list(range(N_BATCHES))


# -- daemon checkpoint / resume ----------------------------------------------

def test_daemon_resume_with_replaying_client(tmp_path):
    """Daemon shuts down mid-stream with a final checkpoint; a restarted
    daemon with resume=True and a client replaying from stream start
    finalizes bit-identically to an uninterrupted run."""
    ref_eng = TrafficEngine(_cfg(), policy="blocking",
                            sinks=[StatsAccumulator(),
                                   MatrixRetention(max_keep=8)])
    ref_eng.run(_source(), seed=SEED)
    ref = ref_eng.finalize()

    batches = _batches()

    def build(resume):
        return AnalyticsDaemon(
            _cfg(), policy="blocking",
            sinks=[StatsAccumulator(), MatrixRetention(max_keep=8)],
            checkpoint_manager=CheckpointManager(tmp_path / "ckpt"),
            checkpoint_every=2, resume=resume, queue_depth=3)

    first = build(resume=False)
    addr = first.bind("tcp://127.0.0.1:0")
    first.start()
    with IngestClient(addr) as ing, DaemonClient(addr) as ctl:
        ing.send_stream(batches[:4])
        ing.end()
        ctl.wait_consumed(4)
        ctl.shutdown()  # final checkpoint at batch 4
    rep1 = first.join()
    assert rep1.batches == 4
    assert rep1.checkpoints_written >= 1
    first.engine.close()  # daemon stopped without finalize: release sinks

    second = build(resume=True)
    addr = second.bind("tcp://127.0.0.1:0")
    second.start()
    with IngestClient(addr) as ing, DaemonClient(addr) as ctl:
        ing.send_stream(batches)  # client replays from stream start
        ing.end()
        ctl.wait_consumed(N_BATCHES)
        ctl.shutdown()
    rep2 = second.join()
    got = second.finalize()
    assert rep2.resumed_from == 4
    assert rep2.batches == N_BATCHES
    _assert_stats_identical(ref["stats"], got["stats"], "daemon-resume")
    _assert_matrices_identical(ref["matrices"], got["matrices"])
