"""Property-based engine equivalence: the core invariant every execution
policy must hold — for the same source, every policy produces identical
analytics; policies are pure scheduling.

The policy matrix is derived from the registry itself
(``policies.canonical_policies()``, i.e. ``_POLICIES`` minus aliases), so a
policy registered without passing the stats/matrix-identity invariant
fails here by construction — there is no hand-maintained list for a new
policy to dodge.  Sharded-family policies (``issubclass(..,
ShardedPolicy)``) are compared on the exact stats subset their fused step
emits; everything else is compared on ALL stats keys and on retained
matrices, bit for bit.

Hypothesis drives (workload, source kind, seed, window_size,
windows_per_batch, depth); a deterministic grid repeats the key cases so
the invariant stays exercised even where hypothesis is absent (the
conftest stub auto-skips ``@given`` tests).  Engines are cached per
geometry so examples reuse jitted stage graphs instead of recompiling.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.window import WindowConfig
from repro.engine import (
    AsyncPipelinedPolicy,
    DeviceSyntheticFlowSource,
    DeviceSyntheticSource,
    DoubleBufferedPolicy,
    MatrixRetention,
    ShardedPolicy,
    Sink,
    StatsAccumulator,
    TrafficEngine,
    canonical_policies,
    make_policy,
)
from repro.engine import policies as policies_mod

# -- the registry-derived policy matrix -------------------------------------
POLICY_NAMES = sorted(canonical_policies())
WORKLOADS = ("packets", "flow")


def _is_sharded(policy_name: str) -> bool:
    return issubclass(canonical_policies()[policy_name], ShardedPolicy)


# Stats the sharded-family policies emit (exact under row ownership);
# stage-graph policies are compared on ALL keys, sharded ones on these.
SHARDED_KEYS = ("valid_packets", "unique_links", "unique_sources",
                "max_packets_per_link", "max_source_packets",
                "max_source_fanout", "src_packet_hist", "src_fanout_hist")

_ENGINES: dict = {}


def _cfg(window_log2, windows_per_batch):
    # anonymization "none" so every policy (incl. sharded) is comparable on
    # raw addresses; anonymized equivalence is covered by the engine tests
    return WindowConfig(window_log2=window_log2,
                        windows_per_batch=windows_per_batch,
                        cap_max_log2=window_log2 + 4,
                        anonymization="none")


def _run(policy_key, cfg, workload, kind, seed, *, depth=None,
         matrices=False, workers=None, submit_batches=None):
    """Run a cached engine; returns (report, per-batch stats, matrices).

    ``kind`` may be a generator-kind string or a Source instance (the
    device-resident sources enter the matrix this way).  ``workers`` and
    ``submit_batches`` forward through ``make_policy``, which drops None.
    """
    cache_key = (policy_key, depth, workers, submit_batches, matrices,
                 workload, cfg)
    if cache_key not in _ENGINES:
        if workers or submit_batches:
            knobs = {"producer_workers": workers,
                     "submit_batches": submit_batches}
            if depth and policy_key == "double_buffered":
                knobs["queue_depth"] = depth
            elif depth:
                knobs["max_in_flight"] = depth
            policy = make_policy(policy_key, **knobs)
        elif policy_key == "double_buffered" and depth:
            policy = DoubleBufferedPolicy(queue_depth=depth)
        elif policy_key == "async_pipelined" and depth:
            policy = AsyncPipelinedPolicy(max_in_flight=depth)
        else:
            policy = policy_key
        sinks = [StatsAccumulator()]
        if matrices:
            sinks.append(MatrixRetention(max_keep=8))
        _ENGINES[cache_key] = TrafficEngine(
            cfg, workload=workload, policy=policy, sinks=sinks
        )
    eng = _ENGINES[cache_key]
    eng.sinks[0] = StatsAccumulator()
    if matrices:
        eng.sinks[1] = MatrixRetention(max_keep=8)
    rep = eng.run(kind, n_batches=2, seed=seed)
    res = eng.finalize()
    return rep, res["stats"]["per_batch"], res.get("matrices")


def _assert_matches_blocking(policy, cfg, workload, kind, seed, *,
                             depth=None):
    """The invariant, one policy vs the blocking reference."""
    sharded = _is_sharded(policy)
    rb, tb, mb = _run("blocking", cfg, workload, kind, seed, matrices=True)
    rp, tp, mp = _run(policy, cfg, workload, kind, seed, depth=depth,
                      matrices=not sharded)

    # identical EngineReport accounting (timings legitimately differ)
    assert rb.batches == rp.batches == 2
    assert rb.packets == rp.packets
    if not sharded:
        assert rb.merge_overflow == rp.merge_overflow

    if sharded:
        # exact on the emitted global-stats subset
        for a, b in zip(tb, tp):
            for k in SHARDED_KEYS:
                np.testing.assert_array_equal(
                    np.asarray(a[k]), np.asarray(b[k]),
                    err_msg=f"{policy}:{k}",
                )
        return
    # stage-graph policy: every stat, bit-identical ...
    for a, b in zip(tb, tp):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"{policy}:{k}")
    # ... and identical retained matrices
    for a, b in zip(mb, mp):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.cols), np.asarray(b.cols))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
        assert int(a.nnz) == int(b.nnz)


def _assert_policy_equivalence(workload, kind, seed, window_log2,
                               windows_per_batch, depth):
    cfg = _cfg(window_log2, windows_per_batch)
    for policy in POLICY_NAMES:
        if policy == "blocking":
            continue
        _assert_matches_blocking(
            policy, cfg, workload, kind, seed,
            depth=depth if policy in ("double_buffered",
                                      "async_pipelined") else None,
        )


# -- registry integrity: the new policies cannot dodge this file ------------
def test_registry_contains_the_async_policies():
    assert "async_pipelined" in POLICY_NAMES
    assert "sharded_pipelined" in POLICY_NAMES
    assert _is_sharded("sharded_pipelined")
    # aliases resolve to canonical classes and stay out of the matrix
    assert "stream" not in POLICY_NAMES
    assert "distributed" not in POLICY_NAMES
    assert (policies_mod._POLICIES["stream"]
            is canonical_policies()["double_buffered"])


# -- the deterministic registry-driven matrix: every policy x workload ------
@pytest.mark.parametrize("workload", WORKLOADS)
@pytest.mark.parametrize("policy",
                         [p for p in POLICY_NAMES if p != "blocking"])
def test_registry_policy_matches_blocking(policy, workload):
    cfg = _cfg(4, 2)
    _assert_matches_blocking(policy, cfg, workload, "uniform", 7)
    _assert_matches_blocking(policy, cfg, workload, "zipf", 13)


# -- telemetry: one packet/warmup accounting rule across the registry -------
@pytest.mark.parametrize("workload", WORKLOADS)
def test_accounting_identical_across_registry(workload):
    """packets_in_item + warmup accounting are the same single rule for
    every registered policy (DESIGN.md), and the async overlap telemetry
    sums sanely: process_s + overlap_s <= elapsed_s by construction."""
    cfg = _cfg(4, 2)
    reports = {}
    for policy in POLICY_NAMES:
        eng = TrafficEngine(cfg, workload=workload, policy=policy,
                            sinks=[StatsAccumulator()])
        rep = eng.run("uniform", n_batches=3, seed=5, warmup_items=1)
        trace = eng.finalize()["stats"]["per_batch"]
        assert len(trace) == rep.batches  # warmup excluded from sinks too
        reports[policy] = rep

    expected_packets = 2 * 2 * 16  # 2 measured batches x [2, 16, 2]
    for policy, rep in reports.items():
        assert rep.batches == 2, policy
        assert rep.packets == expected_packets, policy
        assert rep.overlap_s >= 0.0, policy
        assert rep.max_in_flight >= 1, policy
        assert (rep.process_s + rep.overlap_s
                <= rep.elapsed_s + 0.05), policy
        if not ("pipelined" in policy):
            assert rep.overlap_s == 0.0, policy
            assert rep.max_in_flight == 1, policy


# -- hypothesis: the full invariant over random inputs ----------------------
workloads = st.sampled_from(["packets", "flow"])
kinds = st.sampled_from(["uniform", "zipf"])
seeds = st.integers(0, 2 ** 31 - 1)
window_log2s = st.sampled_from([4, 5])
wpbs = st.sampled_from([2, 4])
depths = st.integers(1, 4)


@given(kinds, seeds, window_log2s, wpbs, depths)
@settings(max_examples=10, deadline=None)
def test_policies_equivalent_packet_source(kind, seed, window_log2, wpb,
                                           depth):
    _assert_policy_equivalence("packets", kind, seed, window_log2, wpb,
                               depth)


@given(kinds, seeds, window_log2s, wpbs, depths)
@settings(max_examples=10, deadline=None)
def test_policies_equivalent_flow_source(kind, seed, window_log2, wpb,
                                         depth):
    _assert_policy_equivalence("flow", kind, seed, window_log2, wpb, depth)


@given(workloads, seeds, depths)
@settings(max_examples=10, deadline=None)
def test_queue_depth_never_changes_stats(workload, seed, depth):
    """Deeper queues/rings change scheduling only: double_buffered and
    async_pipelined at any depth match blocking bit-for-bit."""
    cfg = _cfg(4, 2)
    _, tb, mb = _run("blocking", cfg, workload, "uniform", seed,
                     matrices=True)
    for policy in ("double_buffered", "async_pipelined"):
        _, td, md = _run(policy, cfg, workload, "uniform", seed,
                         depth=depth, matrices=True)
        for a, b in zip(tb, td):
            for k in a:
                np.testing.assert_array_equal(a[k], b[k],
                                              err_msg=f"{policy}:{k}")
        for a, b in zip(mb, md):
            np.testing.assert_array_equal(np.asarray(a.vals),
                                          np.asarray(b.vals))


# -- deterministic floor: the same invariant without hypothesis -------------
@pytest.mark.parametrize("workload,kind,seed,window_log2,wpb,depth", [
    ("packets", "uniform", 7, 4, 2, 2),
    ("packets", "zipf", 13, 5, 4, 3),
    ("flow", "uniform", 7, 4, 2, 3),
    ("flow", "zipf", 29, 5, 4, 2),
])
def test_policy_equivalence_grid(workload, kind, seed, window_log2, wpb,
                                 depth):
    _assert_policy_equivalence(workload, kind, seed, window_log2, wpb,
                               depth)


# -- device-resident sources enter the canonical matrix ---------------------

def _device_source(workload, kind, seed, cfg, n_batches=2):
    cls = (DeviceSyntheticFlowSource if workload == "flow"
           else DeviceSyntheticSource)
    return cls(kind=kind, seed=seed, n_batches=n_batches,
               windows_per_batch=cfg.windows_per_batch,
               window_size=cfg.window_size)


def _assert_same_trace(policy, ref, got, *, sharded, matrices=True):
    (tb, mb), (tp, mp) = ref, got
    if sharded:
        for a, b in zip(tb, tp):
            for k in SHARDED_KEYS:
                np.testing.assert_array_equal(
                    np.asarray(a[k]), np.asarray(b[k]),
                    err_msg=f"{policy}:{k}")
        return
    for a, b in zip(tb, tp):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"{policy}:{k}")
    if matrices:
        for a, b in zip(mb, mp):
            np.testing.assert_array_equal(np.asarray(a.vals),
                                          np.asarray(b.vals))
            assert int(a.nnz) == int(b.nnz)


@pytest.mark.parametrize("workload", WORKLOADS)
@pytest.mark.parametrize("kind", ["uniform", "zipf"])
@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_device_source_matches_host_baseline(policy, workload, kind):
    """Every canonical policy run on the device-resident source produces
    the same stats (and retained matrices) as the blocking policy run on
    the same stream's host-placement twin — device generation is pure
    work relocation, and the keyed-per-window stream is policy-invariant.
    """
    cfg = _cfg(4, 2)
    dev = _device_source(workload, kind, 11, cfg)
    sharded = _is_sharded(policy)
    _, tb, mb = _run("blocking", cfg, workload, dev.host_baseline(), 11,
                     matrices=True)
    _, tp, mp = _run(policy, cfg, workload, dev, 11, matrices=not sharded)
    _assert_same_trace(policy, (tb, mb), (tp, mp), sharded=sharded,
                       matrices=not sharded)


@pytest.mark.parametrize("policy,workers,submit_batches", [
    ("double_buffered", 2, None),
    ("double_buffered", 3, None),
    ("async_pipelined", 2, None),
    ("async_pipelined", 3, 2),
    ("async_pipelined", 1, 3),
    ("sharded_pipelined", 2, 2),
    ("sharded_pipelined", 1, 3),
])
def test_workers_and_batched_submission_keep_the_invariant(
        policy, workers, submit_batches):
    """Multi-worker producers and K-batched submission are pure
    scheduling: stats and retained matrices stay bit-identical to the
    blocking host-baseline run.  n_batches=5 is deliberately not a
    multiple of K, so the padded final partial chunk is exercised (padded
    lanes must never be delivered)."""
    cfg = _cfg(4, 2)
    dev = _device_source("packets", "uniform", 23, cfg, n_batches=5)
    sharded = _is_sharded(policy)
    rb, tb, mb = _run("blocking", cfg, "packets", dev.host_baseline(), 23,
                      matrices=True)
    rp, tp, mp = _run(policy, cfg, "packets", dev, 23,
                      matrices=not sharded, workers=workers,
                      submit_batches=submit_batches)
    assert rb.batches == rp.batches == 5
    assert rb.packets == rp.packets
    assert len(tp) == 5
    assert rp.producer_workers == workers
    assert rp.submit_batches == (submit_batches or 1)
    _assert_same_trace(policy, (tb, mb), (tp, mp), sharded=sharded,
                       matrices=not sharded)


class _IndexTrace(Sink):
    """Records the submission index each consume() call delivers."""

    name = "index_trace"
    requires = ("merge_overflow",)

    def __init__(self):
        self.indices = []

    def consume(self, index, outputs):
        self.indices.append(index)

    def finalize(self):
        return list(self.indices)


def test_sinks_see_submission_order_under_reordering_workers():
    """3 producer workers transform concurrently, so items routinely
    complete out of order — yet sinks must observe batches in submission
    order (the reorder buffer + in-order ring retire guarantee)."""
    cfg = _cfg(4, 2)
    dev = _device_source("packets", "uniform", 31, cfg, n_batches=6)
    trace = _IndexTrace()
    eng = TrafficEngine(cfg, policy=make_policy(
        "async_pipelined", producer_workers=3, max_in_flight=3,
    ), sinks=[StatsAccumulator(), trace])
    rep = eng.run(dev)
    assert rep.batches == 6
    assert trace.indices == list(range(6))
    # and the per-batch stats line up with the blocking host-run, batch
    # for batch — order-sensitive by construction
    _, tb, _ = _run("blocking", cfg, "packets", dev.host_baseline(), 31,
                    matrices=True)
    per_batch = eng.finalize()["stats"]["per_batch"]
    for a, b in zip(tb, per_batch):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
