"""Property-based engine equivalence: the core invariant every execution
policy must hold — for the same source, ``blocking``, ``double_buffered``
(any queue depth), and ``sharded`` produce identical analytics; policies
are pure scheduling.

Hypothesis drives (workload, source kind, seed, window_size,
windows_per_batch, queue_depth); a deterministic grid repeats the key
cases so the invariant stays exercised even where hypothesis is absent
(the conftest stub auto-skips ``@given`` tests).  Engines are cached per
geometry so examples reuse jitted stage graphs instead of recompiling.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.window import WindowConfig
from repro.engine import (
    DoubleBufferedPolicy,
    MatrixRetention,
    StatsAccumulator,
    TrafficEngine,
)

# Stats the sharded policy emits (exact under row ownership); blocking /
# buffered traces are compared on ALL keys, sharded on these.
SHARDED_KEYS = ("valid_packets", "unique_links", "unique_sources",
                "max_packets_per_link", "max_source_packets",
                "max_source_fanout", "src_packet_hist", "src_fanout_hist")

_ENGINES: dict = {}


def _cfg(window_log2, windows_per_batch):
    # anonymization "none" so every policy (incl. sharded) is comparable on
    # raw addresses; anonymized equivalence is covered by the engine tests
    return WindowConfig(window_log2=window_log2,
                        windows_per_batch=windows_per_batch,
                        cap_max_log2=window_log2 + 4,
                        anonymization="none")


def _run(policy_key, cfg, workload, kind, seed, *, depth=None,
         matrices=False):
    """Run a cached engine; returns (report, per-batch stats, matrices)."""
    cache_key = (policy_key, depth, matrices, workload, cfg)
    if cache_key not in _ENGINES:
        policy = (DoubleBufferedPolicy(queue_depth=depth)
                  if policy_key == "double_buffered" and depth
                  else policy_key)
        sinks = [StatsAccumulator()]
        if matrices:
            sinks.append(MatrixRetention(max_keep=8))
        _ENGINES[cache_key] = TrafficEngine(
            cfg, workload=workload, policy=policy, sinks=sinks
        )
    eng = _ENGINES[cache_key]
    eng.sinks[0] = StatsAccumulator()
    if matrices:
        eng.sinks[1] = MatrixRetention(max_keep=8)
    rep = eng.run(kind, n_batches=2, seed=seed)
    res = eng.finalize()
    return rep, res["stats"]["per_batch"], res.get("matrices")


def _assert_policy_equivalence(workload, kind, seed, window_log2,
                               windows_per_batch, depth):
    cfg = _cfg(window_log2, windows_per_batch)
    rb, tb, mb = _run("blocking", cfg, workload, kind, seed, matrices=True)
    rd, td, md = _run("double_buffered", cfg, workload, kind, seed,
                      depth=depth, matrices=True)
    rs, ts, _ = _run("sharded", cfg, workload, kind, seed)

    # identical EngineReport accounting (timings legitimately differ)
    assert rb.batches == rd.batches == rs.batches == 2
    assert rb.packets == rd.packets == rs.packets
    assert rb.merge_overflow == rd.merge_overflow

    # blocking vs double_buffered: every stat, bit-identical
    for a, b in zip(tb, td):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)

    # ... and identical retained matrices
    for a, b in zip(mb, md):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.cols), np.asarray(b.cols))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
        assert int(a.nnz) == int(b.nnz)

    # sharded: exact on its emitted stats subset
    for a, b in zip(tb, ts):
        for k in SHARDED_KEYS:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                          err_msg=k)


workloads = st.sampled_from(["packets", "flow"])
kinds = st.sampled_from(["uniform", "zipf"])
seeds = st.integers(0, 2 ** 31 - 1)
window_log2s = st.sampled_from([4, 5])
wpbs = st.sampled_from([2, 4])
depths = st.integers(1, 4)


@given(kinds, seeds, window_log2s, wpbs, depths)
@settings(max_examples=10, deadline=None)
def test_policies_equivalent_packet_source(kind, seed, window_log2, wpb,
                                           depth):
    _assert_policy_equivalence("packets", kind, seed, window_log2, wpb,
                               depth)


@given(kinds, seeds, window_log2s, wpbs, depths)
@settings(max_examples=10, deadline=None)
def test_policies_equivalent_flow_source(kind, seed, window_log2, wpb,
                                         depth):
    _assert_policy_equivalence("flow", kind, seed, window_log2, wpb, depth)


@given(workloads, seeds, depths)
@settings(max_examples=10, deadline=None)
def test_queue_depth_never_changes_stats(workload, seed, depth):
    """Deeper queues change scheduling only: double_buffered at any depth
    matches blocking bit-for-bit."""
    cfg = _cfg(4, 2)
    _, tb, mb = _run("blocking", cfg, workload, "uniform", seed,
                     matrices=True)
    _, td, md = _run("double_buffered", cfg, workload, "uniform", seed,
                     depth=depth, matrices=True)
    for a, b in zip(tb, td):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for a, b in zip(mb, md):
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))


# -- deterministic floor: the same invariant without hypothesis -------------
@pytest.mark.parametrize("workload,kind,seed,window_log2,wpb,depth", [
    ("packets", "uniform", 7, 4, 2, 2),
    ("packets", "zipf", 13, 5, 4, 3),
    ("flow", "uniform", 7, 4, 2, 3),
    ("flow", "zipf", 29, 5, 4, 2),
])
def test_policy_equivalence_grid(workload, kind, seed, window_log2, wpb,
                                 depth):
    _assert_policy_equivalence(workload, kind, seed, window_log2, wpb,
                               depth)
