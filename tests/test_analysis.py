"""Fixture tests for the repro.analysis static-analysis pass.

Every rule in the catalogue gets at least one *bad* snippet (the finding
fires, with the right rule id on the right line, marked ``# BAD``) and a
*good twin* (the sanctioned way to write the same thing — no finding).
The good twins are the real spec: they pin exactly which patterns the
rules must keep permitting as the repo evolves.

The suite also pins the CI contract end to end: the suppression-comment
grammar, the baseline file format (justifications mandatory, stale
entries reported), the CLI exit codes, and — most importantly — that the
repo's own checked-in baseline matches a fresh scan of the repo.
"""

from __future__ import annotations

from pathlib import Path

import pytest

from repro.analysis import (
    RULE_REGISTRY,
    analyze_source,
    load_baseline,
    scan_paths,
    write_baseline,
)
from repro.analysis.baseline import split_by_baseline
from repro.analysis.cli import main as cli_main

REPO_ROOT = Path(__file__).resolve().parents[1]


def _bad_line(src: str) -> int:
    for i, line in enumerate(src.splitlines(), 1):
        if "# BAD" in line:
            return i
    raise AssertionError("snippet has no '# BAD' marker")


def _findings(src: str, path: str):
    return analyze_source(src, path=path)


# ---------------------------------------------------------------------------
# per-rule bad snippets and good twins
# ---------------------------------------------------------------------------
# rule id -> list of (pretend-path, bad snippet); the '# BAD' marker sits on
# the line the finding must anchor to.
BAD = {
    "use-after-donate": [
        (
            "src/repro/launch/train.py",
            """\
import numpy as np

def run(graph, x):
    step = graph.jitted(donate=True)
    out = step(x)
    return np.asarray(x)  # BAD
""",
        ),
        (
            "src/repro/launch/train.py",
            """\
import jax

def run(step_fn, state, batch):
    step = jax.jit(step_fn, donate_argnums=(0,))
    metrics = step(state, batch)
    return state.params  # BAD
""",
        ),
    ],
    "tracer-leak": [
        (
            "src/repro/engine/stages.py",
            """\
import jax

@jax.jit
def step(x):
    print(x)  # BAD
    return x
""",
        ),
        (
            "src/repro/engine/stages.py",
            """\
import time
import jax

def step(x):
    t = time.perf_counter()  # BAD
    return x, t

step_jit = jax.jit(step)
""",
        ),
        (
            "src/repro/engine/stages.py",
            """\
import jax

TRACE = []

@jax.jit
def step(x):
    TRACE.append(x)  # BAD
    return x
""",
        ),
    ],
    "raw-shard-map": [
        (
            "src/repro/engine/sharded.py",
            """\
from jax.experimental.shard_map import shard_map  # BAD

def run(f, mesh):
    return shard_map(f, mesh=mesh, in_specs=None, out_specs=None)
""",
        ),
        (
            "src/repro/engine/sharded.py",
            """\
import jax

def run(f, mesh):
    return jax.experimental.shard_map.shard_map(f, mesh=mesh)  # BAD
""",
        ),
    ],
    "raw-mesh": [
        (
            "src/repro/engine/sharded.py",
            """\
import jax

def make(devs):
    return jax.sharding.Mesh(devs, ("batch",))  # BAD
""",
        ),
        (
            "src/repro/engine/sharded.py",
            """\
from jax.experimental import mesh_utils

def make(shape):
    return mesh_utils.create_device_mesh(shape)  # BAD
""",
        ),
    ],
    "dtype-discipline": [
        (
            "src/repro/core/build.py",
            """\
import jax.numpy as jnp

def iota(n):
    return jnp.arange(n)  # BAD
""",
        ),
        (
            "src/repro/core/build.py",
            """\
import jax.numpy as jnp

def mix(a, b):
    return jnp.uint32(a) + jnp.int32(b)  # BAD
""",
        ),
    ],
    "thread-shared-state": [
        (
            "src/repro/engine/prefetch.py",
            """\
import threading

class Prefetcher:
    def __init__(self):
        self.count = 0

        def worker():
            self.count += 1  # BAD

        self.t = threading.Thread(target=worker)
""",
        ),
    ],
    "swallowed-exception": [
        (
            "src/repro/engine/policies.py",
            """\
def drain(batches, consume):
    for i, b in enumerate(batches):
        try:
            consume(i, b)
        except Exception:  # BAD
            pass
""",
        ),
        (
            "src/repro/engine/prefetch.py",
            """\
def pull(it):
    try:
        return next(it)
    except:  # BAD
        return None
""",
        ),
    ],
}

# rule id -> (pretend-path, good twin): the sanctioned pattern, no finding.
GOOD = {
    "use-after-donate": [
        (
            "src/repro/launch/train.py",
            """\
def run(graph, state, batch):
    step = graph.jitted(donate=True)
    state, metrics = step(state, batch)
    return state, metrics
""",
        ),
        (
            "src/repro/launch/train.py",
            """\
def run(graph, x):
    step = graph.jitted(donate=True)
    out = step(x)
    assert x.is_deleted()
    return out
""",
        ),
        (
            "src/repro/launch/train.py",
            """\
import numpy as np

def run(graph, x):
    step = graph.jitted(donate=False)
    out = step(x)
    return np.asarray(x)
""",
        ),
    ],
    "tracer-leak": [
        (
            "src/repro/engine/stages.py",
            """\
import jax

@jax.jit
def step(x):
    jax.debug.print("x = {}", x)
    return x
""",
        ),
        (
            "src/repro/engine/stages.py",
            """\
import jax

def step(x):
    acc = []
    acc.append(x)
    return acc

step_jit = jax.jit(step)
""",
        ),
        (
            "src/repro/engine/stages.py",
            """\
import time

def host_loop(x):
    t = time.perf_counter()
    print(x)
    return t
""",
        ),
    ],
    "raw-shard-map": [
        (
            "src/repro/engine/sharded.py",
            """\
from repro.distributed.sharding import shard_map

def run(f, mesh):
    return shard_map(f, mesh=mesh, in_specs=None, out_specs=None)
""",
        ),
    ],
    "raw-mesh": [
        (
            "src/repro/engine/sharded.py",
            """\
from jax.sharding import Mesh

from repro.launch.mesh import make_local_mesh

def make(n: int) -> Mesh:
    return make_local_mesh(n)
""",
        ),
    ],
    "dtype-discipline": [
        (
            "src/repro/core/build.py",
            """\
import jax.numpy as jnp

def iota(n, vals):
    a = jnp.arange(n, dtype=jnp.int32)
    b = jnp.zeros((n,), vals.dtype)
    c = jnp.uint32(n) + jnp.uint32(1)
    d = jnp.uint32(n).astype(jnp.int32) + jnp.int32(1)
    return a, b, c, d
""",
        ),
    ],
    "thread-shared-state": [
        (
            "src/repro/engine/prefetch.py",
            """\
import threading

class Prefetcher:
    def __init__(self):
        self.count = 0
        self._lock = threading.Lock()

        def worker():
            with self._lock:
                self.count += 1

        self.t = threading.Thread(target=worker)
""",
        ),
    ],
    "swallowed-exception": [
        (
            "src/repro/engine/policies.py",
            """\
import warnings

def drain(batches, consume):
    for i, b in enumerate(batches):
        try:
            consume(i, b)
        except Exception as e:
            warnings.warn(f"batch {i} failed: {e}")
""",
        ),
        (
            "src/repro/engine/prefetch.py",
            """\
def pull(it, record_failure):
    try:
        return next(it)
    except StopIteration:
        return None
    except Exception as e:
        record_failure(e)
        raise
""",
        ),
        (
            "src/repro/engine/policies.py",
            """\
def quiesce(inflight, block):
    while inflight:
        out = inflight.popleft()
        try:
            block(out)
        except Exception:  # repro-lint: disable=swallowed-exception
            pass
""",
        ),
    ],
}


def test_every_rule_has_fixtures():
    """The fixture tables and the rule registry must not drift apart."""
    assert set(BAD) == set(RULE_REGISTRY)
    assert set(GOOD) == set(RULE_REGISTRY)


@pytest.mark.parametrize(
    "rule_id,path,src",
    [(rid, p, s) for rid, cases in BAD.items() for p, s in cases],
    ids=[f"{rid}-{i}" for rid, cases in BAD.items()
         for i, _ in enumerate(cases)],
)
def test_bad_snippet_flagged(rule_id, path, src):
    found = _findings(src, path)
    hits = [f for f in found if f.rule == rule_id]
    assert hits, f"{rule_id} did not fire; findings: {found}"
    assert _bad_line(src) in {f.line for f in hits}, (
        f"{rule_id} fired on {[f.line for f in hits]}, "
        f"expected line {_bad_line(src)}"
    )


@pytest.mark.parametrize(
    "rule_id,path,src",
    [(rid, p, s) for rid, cases in GOOD.items() for p, s in cases],
    ids=[f"{rid}-{i}" for rid, cases in GOOD.items()
         for i, _ in enumerate(cases)],
)
def test_good_twin_clean(rule_id, path, src):
    hits = [f for f in _findings(src, path) if f.rule == rule_id]
    assert not hits, f"good twin flagged: {[f.render() for f in hits]}"


# ---------------------------------------------------------------------------
# path scoping and exemptions
# ---------------------------------------------------------------------------
def test_compat_shims_are_exempt_from_their_own_rules():
    """The helper a rule protects may use the raw API it polices."""
    shard_src = BAD["raw-shard-map"][0][1]
    assert not [f for f in _findings(
        shard_src, "src/repro/distributed/sharding.py")
        if f.rule == "raw-shard-map"]
    mesh_src = BAD["raw-mesh"][0][1]
    assert not [f for f in _findings(mesh_src, "src/repro/launch/mesh.py")
                if f.rule == "raw-mesh"]


def test_dtype_rule_only_polices_packed_key_modules():
    src = BAD["dtype-discipline"][0][1]
    assert not [f for f in _findings(src, "src/repro/engine/policies.py")
                if f.rule == "dtype-discipline"]
    assert not [f for f in _findings(src, "tests/test_build.py")
                if f.rule == "dtype-discipline"]


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------
_SUPPRESSED = """\
import jax

@jax.jit
def step(x):
    print(x)  # repro-lint: disable=tracer-leak
    return x
"""

_SUPPRESSED_NEXT_LINE = """\
import jax

@jax.jit
def step(x):
    # repro-lint: disable=tracer-leak
    print(x)
    return x
"""

_SUPPRESSED_FILE = """\
# repro-lint: disable-file=tracer-leak
import jax

@jax.jit
def step(x):
    print(x)
    return x
"""


@pytest.mark.parametrize("src", [_SUPPRESSED, _SUPPRESSED_NEXT_LINE,
                                 _SUPPRESSED_FILE],
                         ids=["trailing", "own-line", "file-wide"])
def test_suppression_comment_silences(src):
    assert not [f for f in _findings(src, "src/repro/engine/stages.py")
                if f.rule == "tracer-leak"]


def test_suppression_is_per_rule_and_optional():
    # a different rule's suppression does not silence tracer-leak
    src = _SUPPRESSED.replace("disable=tracer-leak", "disable=raw-mesh")
    assert [f for f in _findings(src, "src/repro/engine/stages.py")
            if f.rule == "tracer-leak"]
    # and analyze_source can ignore suppressions outright
    assert [f for f in analyze_source(
        _SUPPRESSED, path="src/repro/engine/stages.py",
        respect_suppressions=False) if f.rule == "tracer-leak"]


def test_syntax_error_is_a_finding_not_a_crash():
    found = _findings("def broken(:\n", "src/repro/core/oops.py")
    assert [f for f in found if f.rule == "syntax-error"]


# ---------------------------------------------------------------------------
# baseline + CLI contract
# ---------------------------------------------------------------------------
_VIOLATION = """\
import jax

@jax.jit
def step(x):
    print(x)
    return x
"""


def _tmp_repo(tmp_path: Path) -> Path:
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "bad.py").write_text(_VIOLATION, encoding="utf-8")
    return tmp_path


def test_cli_exit_codes_and_baseline_lifecycle(tmp_path, capsys):
    root = _tmp_repo(tmp_path)
    argv = ["src", "--root", str(root)]

    # fresh violation, no baseline -> fail
    assert cli_main(argv) == 1
    assert "[tracer-leak]" in capsys.readouterr().out

    # grandfather it -> pass
    assert cli_main([*argv, "--write-baseline"]) == 0
    assert cli_main(argv) == 0
    assert "1 baselined" in capsys.readouterr().out.splitlines()[-1]

    # fix the violation -> the baseline entry is stale -> fail again
    (root / "src" / "bad.py").write_text(
        _VIOLATION.replace("print(x)", "pass"), encoding="utf-8")
    assert cli_main(argv) == 1
    assert "STALE" in capsys.readouterr().out


def test_baseline_requires_justifications(tmp_path):
    p = tmp_path / "analysis-baseline.json"
    p.write_text(
        '{"findings": [{"path": "a.py", "line": 1, "rule": "raw-mesh",'
        ' "justification": ""}]}',
        encoding="utf-8",
    )
    with pytest.raises(ValueError, match="justification"):
        load_baseline(p)


def test_baseline_round_trip(tmp_path):
    root = _tmp_repo(tmp_path)
    findings = scan_paths(["src"], root)
    assert findings
    p = tmp_path / "analysis-baseline.json"
    write_baseline(p, findings, justification="test fixture")
    loaded = load_baseline(p)
    new, old, stale = split_by_baseline(findings, loaded)
    assert not new and not stale
    assert len(old) == len(findings)


def test_repo_baseline_matches_fresh_scan():
    """The CI gate itself: a fresh scan of the repo agrees exactly with the
    checked-in baseline — no new findings, no stale entries."""
    baseline = load_baseline(REPO_ROOT / "analysis-baseline.json")
    findings = scan_paths(["src", "tests", "benchmarks"], REPO_ROOT)
    new, _old, stale = split_by_baseline(findings, baseline)
    assert not new, "unbaselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, f"stale baseline entries: {stale}"


def test_list_rules_covers_registry(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULE_REGISTRY:
        assert rid in out
