"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(per-kernel allclose), plus integration through the core/build path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.segsum import ops as segsum_ops
from repro.kernels.segsum import ref as segsum_ref
from repro.kernels.spmm_coo import ops as spmm_ops
from repro.kernels.spmm_coo.ref import spmm_coo_ref
from repro.kernels.sddmm import ops as sddmm_ops
from repro.kernels.sddmm.ref import sddmm_ref
from repro.kernels.embed_bag import ops as eb_ops
from repro.kernels.embed_bag.ref import embedding_bag_ref


@pytest.mark.parametrize("n,nseg", [(64, 4), (100, 10), (2048, 2048),
                                    (4096, 1), (8192, 700), (131072, 40000)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_segsum_sweep(rng, n, nseg, dtype):
    seg = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = (rng.standard_normal(n) * 8).astype(dtype)
    got = segsum_ops.segment_sum_sorted(
        jnp.asarray(vals), jnp.asarray(seg), num_segments=nseg
    )
    want = segsum_ref.segment_sum_sorted_ref(
        jnp.asarray(vals), jnp.asarray(seg), nseg
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


def test_segsum_run_totals_positions(rng):
    seg = np.sort(rng.integers(0, 37, 1000)).astype(np.int32)
    vals = rng.standard_normal(1000).astype(np.float32)
    got = segsum_ops.run_totals(jnp.asarray(vals), jnp.asarray(seg))
    want = segsum_ref.run_totals_ref(jnp.asarray(vals), jnp.asarray(seg))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


def test_segsum_in_matrix_build(rng):
    """use_kernel path through dedup == jnp path."""
    from repro.core.build import matrix_build

    src = rng.integers(0, 100, 4096).astype(np.uint32)
    dst = rng.integers(0, 100, 4096).astype(np.uint32)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=128,
                     ncols=128, use_kernel=True)
    B = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=128,
                     ncols=128, use_kernel=False)
    assert int(A.nnz) == int(B.nnz)
    np.testing.assert_array_equal(np.asarray(A.vals), np.asarray(B.vals))
    np.testing.assert_array_equal(np.asarray(A.rows), np.asarray(B.rows))


@pytest.mark.parametrize(
    "nr,nc,ne,d,tr,tc,cap",
    [
        (64, 64, 512, 16, 32, 32, 64),
        (128, 256, 2048, 33, 64, 128, 128),
        (1000, 1000, 16384, 64, 256, 256, 64),  # exercises overflow fixup
        (16, 512, 4096, 8, 16, 512, 512),
    ],
)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_spmm_sweep(rng, nr, nc, ne, d, tr, tc, cap, dtype):
    rows = rng.integers(0, nr, ne).astype(np.uint32)
    cols = rng.integers(0, nc, ne).astype(np.uint32)
    vals = rng.standard_normal(ne).astype(np.float32)
    x = rng.standard_normal((nc, d)).astype(dtype)
    nv = ne - 5
    got = spmm_ops.spmm_coo(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
        jnp.asarray(x), nv, num_rows=nr, tile_r=tr, tile_c=tc, cap=cap,
    )
    want = spmm_coo_ref(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
        jnp.asarray(x), nv, num_rows=nr,
    )
    tol = 2e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("ne,d", [(512, 16), (4096, 64), (10556, 8)])
def test_sddmm_sweep(rng, ne, d):
    nr, nc = 300, 280
    rows = rng.integers(0, nr, ne).astype(np.uint32)
    cols = rng.integers(0, nc, ne).astype(np.uint32)
    u = rng.standard_normal((nr, d)).astype(np.float32)
    v = rng.standard_normal((nc, d)).astype(np.float32)
    got = sddmm_ops.sddmm(jnp.asarray(rows), jnp.asarray(cols),
                          jnp.asarray(u), jnp.asarray(v), ne - 3,
                          tile_r=128, tile_c=128, cap=64)
    want = sddmm_ref(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(u),
                     jnp.asarray(v), ne - 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("vocab,n,nbag", [(1000, 512, 64), (5000, 4096, 256)])
def test_embed_bag_sweep(rng, mode, vocab, n, nbag):
    table = rng.standard_normal((vocab, 32)).astype(np.float32)
    idx = rng.integers(0, vocab, n).astype(np.int32)
    bags = np.sort(rng.integers(0, nbag, n)).astype(np.int32)
    w = rng.standard_normal(n).astype(np.float32) if mode == "sum" else None
    got = eb_ops.embedding_bag(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(bags),
        num_bags=nbag, weights=None if w is None else jnp.asarray(w),
        n_valid=n - 3, mode=mode, tile_r=64, tile_c=512, cap=128,
    )
    want = embedding_bag_ref(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(bags), nbag,
        None if w is None else jnp.asarray(w), n - 3, mode,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_bucketing_overflow_exact(rng):
    from repro.kernels.bucketing import bucket_coo_2d

    rows = rng.integers(0, 64, 1000).astype(np.uint32)
    cols = rng.integers(0, 64, 1000).astype(np.uint32)
    vals = np.ones(1000, np.float32)
    b = bucket_coo_2d(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals), 1000,
        num_rows=64, num_cols=64, tile_r=32, tile_c=32, cap=8,
    )
    # overflow + stored == total
    stored = int((np.asarray(b.vals) != 0).sum())
    assert stored + int(b.overflow) == 1000
