"""Algebraic property tests (hypothesis): the GraphBLAS laws the system's
distributed correctness rests on.

The merge tree and the distributed psum/all_to_all analytics are only exact
because ewise_add(plus) is associative+commutative, build is
order-invariant, and reductions are monoid homomorphisms — so these are
tested as laws, not examples.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import from_dense, matrix_build, ops, types


def _dense(seed, n=12, density=0.35):
    rng = np.random.default_rng(seed)
    d = rng.integers(1, 8, (n, n)).astype(np.int32)
    return (d * (rng.random((n, n)) < density)).astype(np.int32)


def _np(A, n=12):
    r, c, v = A.entries()
    out = np.zeros((n, n), np.int64)
    out[r.astype(int), c.astype(int)] = v
    return out


seeds = st.integers(0, 2 ** 31 - 1)


@given(seeds, seeds, seeds)
@settings(max_examples=15)
def test_ewise_add_associative(s1, s2, s3):
    A, B, C = (from_dense(jnp.asarray(_dense(s))) for s in (s1, s2, s3))
    left = ops.ewise_add(ops.ewise_add(A, B).matrix, C).matrix
    right = ops.ewise_add(A, ops.ewise_add(B, C).matrix).matrix
    assert np.array_equal(_np(left), _np(right))


@given(seeds, seeds)
@settings(max_examples=15)
def test_mxm_distributes_over_ewise_add(s1, s2):
    """A @ (B + C) == A@B + A@C over plus_times."""
    A = from_dense(jnp.asarray(_dense(s1)))
    B = from_dense(jnp.asarray(_dense(s2)))
    C = from_dense(jnp.asarray(_dense(s1 ^ s2)))
    bc = ops.ewise_add(B, C).matrix
    left = ops.mxm(A, bc, expansion_capacity=4096).matrix
    ab = ops.mxm(A, B, expansion_capacity=4096).matrix
    ac = ops.mxm(A, C, expansion_capacity=4096).matrix
    right = ops.ewise_add(ab, ac).matrix
    assert np.array_equal(_np(left), _np(right))


@given(seeds)
@settings(max_examples=15)
def test_build_order_invariance(seed):
    """Permuting the packet stream never changes the matrix."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 20, 300).astype(np.uint32)
    dst = rng.integers(0, 20, 300).astype(np.uint32)
    perm = rng.permutation(300)
    A = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=32, ncols=32)
    B = matrix_build(jnp.asarray(src[perm]), jnp.asarray(dst[perm]),
                     nrows=32, ncols=32)
    np.testing.assert_array_equal(np.asarray(A.rows), np.asarray(B.rows))
    np.testing.assert_array_equal(np.asarray(A.vals), np.asarray(B.vals))


@given(seeds, st.integers(1, 4))
@settings(max_examples=15)
def test_split_build_merge_equals_single_build(seed, parts):
    """The distributed invariant: building shards and ewise_add-merging ==
    building everything at once (this is why window/device sharding is
    exact)."""
    rng = np.random.default_rng(seed)
    n = 64 * parts
    src = rng.integers(0, 30, n).astype(np.uint32)
    dst = rng.integers(0, 30, n).astype(np.uint32)
    whole = matrix_build(jnp.asarray(src), jnp.asarray(dst), nrows=32,
                         ncols=32)
    shards = [
        matrix_build(jnp.asarray(src[i::parts]), jnp.asarray(dst[i::parts]),
                     nrows=32, ncols=32)
        for i in range(parts)
    ]
    acc = shards[0]
    for sh in shards[1:]:
        acc = ops.ewise_add(acc, sh).matrix
    assert np.array_equal(_np(whole, 32), _np(acc, 32))


@given(seeds)
@settings(max_examples=15)
def test_transpose_involution(seed):
    A = from_dense(jnp.asarray(_dense(seed)))
    att = ops.transpose(ops.transpose(A))
    assert np.array_equal(_np(A), _np(att))


@given(seeds)
@settings(max_examples=15)
def test_reduce_is_homomorphism(seed):
    """reduce(A + B) == reduce(A) + reduce(B) for the plus monoid."""
    A = from_dense(jnp.asarray(_dense(seed)))
    B = from_dense(jnp.asarray(_dense(seed ^ 0xABCD)))
    merged = ops.ewise_add(A, B).matrix
    lhs = int(ops.reduce_scalar(merged))
    rhs = int(ops.reduce_scalar(A)) + int(ops.reduce_scalar(B))
    assert lhs == rhs
    # and for max: reduce_max(A+B) >= max(reduce_max(A), reduce_max(B))
    mx = int(ops.reduce_scalar(merged, types.MAX_MONOID))
    assert mx >= max(int(ops.reduce_scalar(A, types.MAX_MONOID)),
                     int(ops.reduce_scalar(B, types.MAX_MONOID)))
