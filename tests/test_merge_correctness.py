"""Merge correctness: merge_tree overflow accounting, and the counting
fast-path dedup vs the general dedup — including packets whose key equals
SENTINEL (255.255.255.255 is a legal address, padding is positional)."""

from collections import Counter

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.build import (
    build_windows_batched,
    count_dedup_sorted,
    dedup_sorted,
    lex_sort,
    matrix_build,
)
from repro.core.hypersparse import SENTINEL
from repro.core.window import WindowConfig, merge_tree

SENT = int(np.uint32(SENTINEL))


def _disjoint_windows(n_windows: int, window: int) -> np.ndarray:
    """[W, n, 2] batches where every (src, dst) key is globally unique."""
    base = np.arange(n_windows * window, dtype=np.uint32)
    pkts = np.stack([base, base + np.uint32(1 << 20)], axis=1)
    return pkts.reshape(n_windows, window, 2)


# -- merge_tree overflow accounting ----------------------------------------
def test_merge_tree_no_overflow_when_capacity_suffices():
    cfg = WindowConfig(window_log2=4, windows_per_batch=4, cap_max_log2=10)
    stack = build_windows_batched(jnp.asarray(_disjoint_windows(4, 16)))
    merged, overflow = merge_tree(stack, cfg)
    assert int(overflow) == 0
    assert int(merged.nnz) == 4 * 16


def test_merge_tree_overflow_is_counted_exactly_two_windows():
    # cap_max = 16: merging two all-unique 16-entry windows (union 32)
    # must keep 16 and report exactly 16 dropped.
    cfg = WindowConfig(window_log2=4, windows_per_batch=2, cap_max_log2=4)
    stack = build_windows_batched(jnp.asarray(_disjoint_windows(2, 16)))
    merged, overflow = merge_tree(stack, cfg)
    assert int(merged.nnz) == 16
    assert int(overflow) == 16


def test_merge_tree_overflow_accumulates_across_levels():
    # W=4, cap 16 at every level:
    #   level 1: two merges of 32-unique -> 16 kept, 16 dropped each (32)
    #   level 2: union of two disjoint 16-sets = 32 -> 16 kept, 16 dropped
    cfg = WindowConfig(window_log2=4, windows_per_batch=4, cap_max_log2=4)
    stack = build_windows_batched(jnp.asarray(_disjoint_windows(4, 16)))
    merged, overflow = merge_tree(stack, cfg)
    assert int(merged.nnz) == 16
    assert int(overflow) == 2 * 16 + 16


def test_merge_tree_rejects_non_power_of_two():
    cfg = WindowConfig(window_log2=4, windows_per_batch=3)
    stack = build_windows_batched(jnp.asarray(_disjoint_windows(3, 16)))
    with pytest.raises(AssertionError, match="power of two"):
        merge_tree(stack, cfg)


# -- counting fast path vs general dedup, sentinel-keyed packets -----------
def _sorted_streams(rows, cols, n_valid):
    """matrix_build's pre-dedup contract: padding keys forced to SENTINEL,
    then lexicographic sort (stability keeps real entries ahead of padding
    within an equal-key run)."""
    n = rows.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    valid = iota < n_valid
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)
    return lex_sort(rows, cols)


def _packets_with_sentinels(rng, n_valid):
    rows = rng.integers(0, 8, n_valid).astype(np.uint32)
    cols = rng.integers(0, 8, n_valid).astype(np.uint32)
    # legal 255.255.255.255 traffic, duplicated, in the middle of the data
    rows[5:9] = SENT
    cols[5:7] = SENT
    cols[7:9] = 3
    rows[0] = SENT  # (SENT, small): sorts between real keys and (SENT, SENT)
    cols[0] = 0
    return rows, cols


@pytest.mark.parametrize("n_pad", [0, 7])
def test_count_dedup_equals_general_dedup(rng, n_pad):
    n_valid = 40
    rows_np, cols_np = _packets_with_sentinels(rng, n_valid)
    rows = jnp.concatenate([
        jnp.asarray(rows_np), jnp.zeros((n_pad,), jnp.uint32)
    ])
    cols = jnp.concatenate([
        jnp.asarray(cols_np), jnp.zeros((n_pad,), jnp.uint32)
    ])
    srows, scols = _sorted_streams(rows, cols, n_valid)

    r1, c1, v1, nnz1 = count_dedup_sorted(srows, scols, jnp.int32(n_valid))
    ones = jnp.ones_like(srows, dtype=jnp.int32)
    r2, c2, v2, nnz2 = dedup_sorted(srows, scols, ones, jnp.int32(n_valid))

    assert int(nnz1) == int(nnz2)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    # both match the host oracle over the valid prefix
    oracle = Counter(zip(rows_np.tolist(), cols_np.tolist()))
    assert int(nnz1) == len(oracle)
    got = {
        (int(r), int(c)): int(v)
        for r, c, v in zip(
            np.asarray(r1)[: int(nnz1)],
            np.asarray(c1)[: int(nnz1)],
            np.asarray(v1)[: int(nnz1)],
        )
    }
    assert got == dict(oracle)


def test_matrix_build_fast_path_matches_general_with_sentinel_keys(rng):
    n_valid = 32
    rows_np, cols_np = _packets_with_sentinels(rng, n_valid)
    rows, cols = jnp.asarray(rows_np), jnp.asarray(cols_np)
    A_fast = matrix_build(rows, cols, count_fast_path=True)
    A_gen = matrix_build(rows, cols, count_fast_path=False)
    assert int(A_fast.nnz) == int(A_gen.nnz)
    np.testing.assert_array_equal(np.asarray(A_fast.rows),
                                  np.asarray(A_gen.rows))
    np.testing.assert_array_equal(np.asarray(A_fast.cols),
                                  np.asarray(A_gen.cols))
    np.testing.assert_array_equal(np.asarray(A_fast.vals),
                                  np.asarray(A_gen.vals))
    # the all-sentinel key is real data here, not padding
    oracle = Counter(zip(rows_np.tolist(), cols_np.tolist()))
    assert oracle[(SENT, SENT)] >= 2
    r, c, v = A_fast.entries()
    got = {(int(a), int(b)): int(x) for a, b, x in zip(r, c, v)}
    assert got[(SENT, SENT)] == oracle[(SENT, SENT)]
