"""End-to-end integration: training loop + checkpoint restart + serving."""

import tempfile

import numpy as np
import pytest


@pytest.mark.slow
def test_train_loss_decreases_and_restarts():
    from repro.launch.train import main

    with tempfile.TemporaryDirectory() as d:
        losses = main([
            "--arch", "qwen1.5-0.5b", "--preset", "smoke",
            "--steps", "30", "--global-batch", "8", "--seq-len", "64",
            "--ckpt-dir", d, "--ckpt-every", "10", "--log-every", "100",
        ])
        assert len(losses) == 30
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), (
            "loss did not decrease"
        )
        # restart: picks up at step 30 -> only 10 more steps run
        losses2 = main([
            "--arch", "qwen1.5-0.5b", "--preset", "smoke",
            "--steps", "40", "--global-batch", "8", "--seq-len", "64",
            "--ckpt-dir", d, "--ckpt-every", "10", "--log-every", "100",
        ])
        assert len(losses2) == 10
        assert np.mean(losses2) < np.mean(losses[:5])


@pytest.mark.slow
def test_serve_generates():
    from repro.launch.serve import main

    tokens = main(["--arch", "qwen1.5-0.5b", "--batch", "2",
                   "--prompt-len", "8", "--new-tokens", "6"])
    assert tokens.shape == (2, 6)
    assert (tokens >= 0).all()
