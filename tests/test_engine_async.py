"""Overlap-correctness for the async-dispatch policies: the properties that
make ``async_pipelined``/``sharded_pipelined`` *safe*, beyond the
stats-identity the equivalence suite already pins.

* in-flight depth never exceeds ``max_in_flight`` (host-side outstanding
  counter + the report's ``max_in_flight`` gauge);
* sinks observe results in submission order (planted per-batch tags);
* donated input buffers are unobservable after dispatch, yet every batch's
  outputs still round-trip its planted values (donation recycles buffers,
  never corrupts results);
* a mid-stream source failure drains every submitted batch — no leaked
  in-flight work;
* ``sync_timing`` restores the Fig.-2 per-batch measurement semantics.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.window import WindowConfig
from repro.engine import (
    AsyncPipelinedPolicy,
    DoubleBufferedPolicy,
    IterableSource,
    MatrixRetention,
    ShardedPipelinedPolicy,
    StageGraph,
    StatsAccumulator,
    TrafficEngine,
)
from repro.core.hypersparse import SENTINEL


def _cfg(**kw):
    kw.setdefault("window_log2", 4)
    kw.setdefault("windows_per_batch", 2)
    kw.setdefault("cap_max_log2", 8)
    kw.setdefault("anonymization", "none")
    return WindowConfig(**kw)


def _batches(n, shape=(2, 16, 2), tag_fn=None):
    out = []
    for i in range(n):
        b = np.zeros(shape, np.uint32)
        b[:] = tag_fn(i) if tag_fn else i
        out.append(b)
    return out


# -- in-flight depth bound --------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 3])
def test_in_flight_depth_never_exceeds_k(k):
    n = 8
    counts = {"submitted": 0, "retired": 0}
    step = jax.jit(lambda x: x.astype(jnp.uint32).sum())

    def process(x):
        # called at submission: everything submitted-but-not-retired is in
        # flight; with this one added, the ring must still be within k
        counts["submitted"] += 1
        assert counts["submitted"] - counts["retired"] <= k
        return step(x)

    def consume(idx, out):
        counts["retired"] += 1

    policy = AsyncPipelinedPolicy(max_in_flight=k, donate=False)
    rep = policy.run(
        IterableSource(it=_batches(n)), process,
        packets_per_item=32, consume=consume,
    )
    assert counts["submitted"] == counts["retired"] == n
    assert 1 <= rep.max_in_flight <= k
    assert len(policy._inflight) == 0


# -- submission-order delivery ----------------------------------------------
def test_consume_observes_results_in_submission_order():
    n = 9
    step = jax.jit(lambda x: x[0, 0, 0])  # the planted per-batch tag
    seen = []

    policy = AsyncPipelinedPolicy(max_in_flight=3, donate=False)
    rep = policy.run(
        IterableSource(it=_batches(n)), step, packets_per_item=32,
        consume=lambda idx, out: seen.append((idx, int(out))),
    )
    # every batch's result arrived, in submission order, tagged correctly
    assert seen == [(i, i) for i in range(n)]
    # report.results kept the same order
    assert [int(r) for r in rep.results] == list(range(n))


def test_consume_order_with_warmup_and_engine():
    """Through the engine: warmup batches are invisible to sinks; measured
    batches arrive in order under the async policy."""
    cfg = _cfg()
    eng = TrafficEngine(cfg, policy=AsyncPipelinedPolicy(max_in_flight=3),
                        sinks=[StatsAccumulator()])
    rep = eng.run("uniform", n_batches=5, seed=3, warmup_items=2)
    trace = eng.finalize()["stats"]["per_batch"]
    assert rep.batches == 3
    assert len(trace) == 3


# -- donation ---------------------------------------------------------------
def test_donated_input_unobservable_but_results_round_trip():
    """The stage graph's donated jit recycles the input buffer (it becomes
    the anonymized-packets output), so the submitted array is deleted —
    and the outputs still carry exactly the planted per-batch values."""
    cfg = _cfg()
    # "packets" output aliases the [W, n, 2] uint32 input, so donation is
    # usable (not just a jax-level mark)
    graph = StageGraph(cfg, outputs=("stats", "merge_overflow", "packets"))
    step = graph.jitted(donate=True)

    batch = np.full((2, 16, 2), 7, np.uint32)
    dev = jax.device_put(batch)
    out = jax.block_until_ready(step(dev))
    assert dev.is_deleted()  # not observable after donation
    with pytest.raises(RuntimeError):
        # deliberate read of a donated buffer: the test asserts it raises
        np.asarray(dev)  # repro-lint: disable=use-after-donate
    # anonymization "none": packets pass through bit-identically
    np.testing.assert_array_equal(np.asarray(out["packets"]), batch)
    assert int(out["stats"]["valid_packets"]) == 32

    # the undonated path must NOT delete its input
    dev2 = jax.device_put(batch)
    jax.block_until_ready(graph(dev2))
    assert not dev2.is_deleted()


def test_async_engine_planted_values_round_trip_per_batch():
    """Each batch is one planted link (i, i+1); with donation + a 3-deep
    ring, every retained matrix must still hold exactly its own batch's
    link — donated buffers are recycled, never cross-contaminated."""
    cfg = _cfg()
    n = 6
    per_batch = 2 * 16  # all packets in batch i hit link (i, i+1)
    batches = []
    for i in range(n):
        b = np.zeros((2, 16, 2), np.uint32)
        b[..., 0] = i
        b[..., 1] = i + 1
        batches.append(b)

    eng = TrafficEngine(
        cfg, policy=AsyncPipelinedPolicy(max_in_flight=3),
        sinks=[MatrixRetention(max_keep=n)],
    )
    eng.run(IterableSource(it=batches))
    kept = eng.finalize()["matrices"]
    assert len(kept) == n
    for i, m in enumerate(kept):
        rows = np.asarray(m.rows)
        live = rows != SENTINEL
        assert int(m.nnz) == 1
        assert rows[live][0] == i
        assert np.asarray(m.cols)[live][0] == i + 1
        assert np.asarray(m.vals)[live][0] == per_batch


# -- failure drain ----------------------------------------------------------
class _NicDied(Exception):
    pass


def test_mid_stream_source_exception_leaves_no_in_flight_work():
    def dying_source():
        yield from _batches(3)
        raise _NicDied("receive queue reset")

    policy = AsyncPipelinedPolicy(max_in_flight=4, donate=False)
    step = jax.jit(lambda x: x.astype(jnp.uint32).sum())
    with pytest.raises(_NicDied, match="receive queue reset"):
        policy.run(IterableSource(it=dying_source()), step,
                   packets_per_item=32)
    assert len(policy._inflight) == 0  # everything submitted was drained


def test_mid_stream_exception_through_engine():
    cfg = _cfg()
    policy = AsyncPipelinedPolicy(max_in_flight=4)

    def dying_source():
        rng = np.random.default_rng(0)
        for _ in range(3):
            yield rng.integers(0, 1 << 16, (2, 16, 2), dtype=np.uint32)
        raise _NicDied("link flap")

    eng = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
    with pytest.raises(_NicDied):
        eng.run(IterableSource(it=dying_source()))
    assert len(policy._inflight) == 0


@pytest.mark.parametrize("policy_factory", [
    lambda: DoubleBufferedPolicy(queue_depth=2),
    lambda: AsyncPipelinedPolicy(max_in_flight=3),
], ids=["double_buffered", "async_pipelined"])
def test_failed_run_keeps_produce_accounting_observable(policy_factory):
    """The prefetcher stays on the policy instance after a failed run, and
    its locked produce_s snapshot banks every device_put — including work
    in flight when the stream died — so post-mortems see real IO time."""
    cfg = _cfg()
    policy = policy_factory()

    def dying_source():
        rng = np.random.default_rng(3)
        for _ in range(4):
            yield rng.integers(0, 1 << 16, (2, 16, 2), dtype=np.uint32)
        raise _NicDied("cable pulled")

    eng = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
    with pytest.raises(_NicDied):
        eng.run(IterableSource(it=dying_source()))
    pf = policy._prefetcher
    assert pf.closed
    assert not pf._thread.is_alive()
    assert pf.produce_s > 0.0  # the 4 produced batches' transfer time
    assert pf.produce_time() == pytest.approx(pf.produce_s)


# -- sharded_pipelined ------------------------------------------------------
def test_sharded_pipelined_depth_and_order():
    cfg = _cfg()
    policy = ShardedPipelinedPolicy(max_in_flight=2, queue_depth=2)
    seen = []
    eng = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
    orig_dispatch = eng._dispatch
    eng._dispatch = lambda idx, out: (seen.append(idx),
                                      orig_dispatch(idx, out))
    rep = eng.run("uniform", n_batches=4, seed=1)
    assert seen == [0, 1, 2, 3]
    assert 1 <= rep.max_in_flight <= 2
    assert len(policy._inflight) == 0
    assert rep.process_s + rep.overlap_s <= rep.elapsed_s + 0.05


# -- timing semantics -------------------------------------------------------
def test_sync_timing_escape_hatch():
    """sync_timing retires each batch at submission: depth collapses to 1
    and stats stay identical — the Fig.-2 comparable measurement."""
    cfg = _cfg()
    traces = {}
    for name, policy in (
        ("async", AsyncPipelinedPolicy(max_in_flight=3)),
        ("sync", AsyncPipelinedPolicy(max_in_flight=3, sync_timing=True)),
    ):
        eng = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
        rep = eng.run("uniform", n_batches=3, seed=9)
        traces[name] = eng.finalize()["stats"]["per_batch"]
        if name == "sync":
            assert rep.max_in_flight == 1
    for a, b in zip(traces["async"], traces["sync"]):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_max_in_flight_must_be_positive():
    with pytest.raises(ValueError, match="max_in_flight"):
        AsyncPipelinedPolicy(max_in_flight=0)
    with pytest.raises(ValueError, match="max_in_flight"):
        ShardedPipelinedPolicy(max_in_flight=0)
