"""Crash-consistent engine checkpoints and kill/resume equivalence.

The headline invariant of the fault-tolerant runtime: kill an engine
mid-run at a window boundary, resume from the latest checkpoint, and the
final stats and retained matrices are bit-identical to the uninterrupted
run — for every canonical policy, under injected source faults.  Plus the
serialization/manager plumbing that invariant rests on: the portable
(self-describing) checkpoint encoding, save-lock correctness under
async/direct save races, and stale-tmp hygiene.
"""

import threading

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.core.window import WindowConfig
from repro.engine import (
    FaultPlan,
    FaultTolerance,
    MatrixRetention,
    ShardedPolicy,
    StatsAccumulator,
    TrafficEngine,
    WorkerDiedError,
    canonical_policies,
    make_policy,
)
from repro.engine.source import (
    DeviceSyntheticSource,
    SkippingSource,
    fast_forward,
)

POLICY_NAMES = sorted(canonical_policies())
N_BATCHES = 6
SEED = 23


def _is_sharded(policy_name: str) -> bool:
    return issubclass(canonical_policies()[policy_name], ShardedPolicy)


def _cfg():
    return WindowConfig(window_log2=6, windows_per_batch=4,
                        anonymization="none")


def _source(n_batches=N_BATCHES):
    # host placement: the device-keyed stream (pure function of the global
    # window index -> exact resume cursor), materialized as numpy so every
    # policy (including sharded's shard transfer) accepts it
    return DeviceSyntheticSource(kind="uniform", seed=SEED,
                                 n_batches=n_batches, windows_per_batch=4,
                                 window_size=64, placement="host")


def _sinks(policy_name):
    sinks = [StatsAccumulator()]
    if not _is_sharded(policy_name):
        sinks.append(MatrixRetention(max_keep=8))
    return sinks


def _engine(policy_name, **policy_knobs):
    policy = (make_policy(policy_name, **policy_knobs) if policy_knobs
              else policy_name)
    return TrafficEngine(_cfg(), policy=policy, sinks=_sinks(policy_name))


def _results(engine):
    res = engine.finalize()
    return res["stats"], res.get("matrices")


def _assert_identical(ref, got, label):
    ref_stats, ref_mats = ref
    got_stats, got_mats = got
    assert got_stats["batches"] == ref_stats["batches"], label
    assert ref_stats.keys() == got_stats.keys()
    for k in ref_stats:
        if k == "per_batch":
            for a, b in zip(ref_stats[k], got_stats[k]):
                for kk in a:
                    np.testing.assert_array_equal(
                        np.asarray(a[kk]), np.asarray(b[kk]),
                        err_msg=f"{label}:per_batch:{kk}")
            continue
        np.testing.assert_array_equal(
            np.asarray(ref_stats[k]), np.asarray(got_stats[k]),
            err_msg=f"{label}:{k}")
    if ref_mats is None:
        assert got_mats is None
        return
    assert len(ref_mats) == len(got_mats), label
    for a, b in zip(ref_mats, got_mats):
        np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
        np.testing.assert_array_equal(np.asarray(a.cols), np.asarray(b.cols))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
        assert int(a.nnz) == int(b.nnz)


_REFERENCE: dict = {}


def _reference(policy_name):
    """The uninterrupted fault-free run, cached per policy."""
    if policy_name not in _REFERENCE:
        eng = _engine(policy_name)
        rep = eng.run(_source(), n_batches=N_BATCHES, seed=SEED)
        assert rep.batches == N_BATCHES
        _REFERENCE[policy_name] = _results(eng)
    return _REFERENCE[policy_name]


def _crash_and_resume(policy_name, tmp_path, *, checkpoint_every,
                      crash_at, exc=RuntimeError, match="injected crash",
                      **policy_knobs):
    """Run with a crash planned at stream batch ``crash_at``; resume from
    the checkpoint dir with a fresh engine; return (resume report, results).
    """
    mgr = CheckpointManager(tmp_path / "ckpt")
    ft = FaultTolerance(
        plan=FaultPlan.parse(f"transient:1@1,{'kill-worker' if exc is WorkerDiedError else 'crash'}@{crash_at}"))
    crashed = _engine(policy_name, **policy_knobs)
    with pytest.raises(exc, match=match):
        crashed.run(_source(), n_batches=N_BATCHES, seed=SEED,
                    fault_tolerance=ft, checkpoint_every=checkpoint_every,
                    checkpoint_manager=mgr)

    resumed = _engine(policy_name, **policy_knobs)
    rep = resumed.run(_source(), n_batches=N_BATCHES, seed=SEED,
                      checkpoint_every=checkpoint_every,
                      checkpoint_manager=CheckpointManager(tmp_path / "ckpt"),
                      resume=True)
    return rep, _results(resumed)


# ---------------------------------------------------------------------------
# THE chaos invariant: every canonical policy, kill + resume == uninterrupted
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy_name", POLICY_NAMES)
def test_kill_resume_bit_identical(policy_name, tmp_path):
    ref = _reference(policy_name)
    rep, got = _crash_and_resume(policy_name, tmp_path,
                                 checkpoint_every=1, crash_at=4)
    assert rep.batches == N_BATCHES
    assert rep.packets == N_BATCHES * 4 * 64
    _assert_identical(ref, got, policy_name)


@pytest.mark.parametrize("policy_name", ["blocking", "async_pipelined"])
def test_kill_resume_with_sparser_checkpoints(policy_name, tmp_path):
    ref = _reference(policy_name)
    rep, got = _crash_and_resume(policy_name, tmp_path,
                                 checkpoint_every=2, crash_at=5)
    assert rep.batches == N_BATCHES
    _assert_identical(ref, got, policy_name)


def test_blocking_resume_starts_mid_stream(tmp_path):
    """With checkpoint_every=1 under the blocking policy, every delivered
    batch checkpoints before the crash — the resume must NOT cold-start."""
    ref = _reference("blocking")
    rep, got = _crash_and_resume("blocking", tmp_path,
                                 checkpoint_every=1, crash_at=4)
    assert rep.resumed_from == 4
    assert rep.checkpoints_written == 2  # batches 5 and 6
    # cumulative accounting folds the checkpointed counters in.  The crash
    # fault itself fired AFTER the last checkpoint was written, so it is
    # (correctly) absent: nothing survived it to account for.
    assert rep.retries == 1 and rep.faults_injected == 1
    _assert_identical(ref, got, "blocking-mid-stream")


def test_kill_worker_chaos_resume(tmp_path):
    """A prefetch worker dying mid-read (WorkerKilled -> last rites ->
    WorkerDiedError) is also recoverable by resume.  The async ring may
    discard in-flight batches before the first dispatch, so a cold-start
    resume is valid here — only equivalence is asserted."""
    ref = _reference("async_pipelined")
    rep, got = _crash_and_resume(
        "async_pipelined", tmp_path, checkpoint_every=1, crash_at=4,
        exc=WorkerDiedError, match="died while producing",
        producer_workers=2)
    assert rep.batches == N_BATCHES
    _assert_identical(ref, got, "kill-worker")


def test_restore_onto_different_policy(tmp_path):
    """Checkpoints are policy-agnostic: crash under blocking, resume under
    double_buffered — still bit-identical to the uninterrupted run."""
    ref = _reference("blocking")
    mgr = CheckpointManager(tmp_path / "ckpt")
    crashed = _engine("blocking")
    with pytest.raises(RuntimeError, match="injected crash"):
        crashed.run(_source(), n_batches=N_BATCHES, seed=SEED,
                    fault_tolerance=FaultTolerance(
                        plan=FaultPlan.parse("crash@3")),
                    checkpoint_every=1, checkpoint_manager=mgr)

    resumed = _engine("double_buffered")
    rep = resumed.run(_source(), n_batches=N_BATCHES, seed=SEED,
                      checkpoint_every=1, checkpoint_manager=mgr,
                      resume=True)
    assert rep.resumed_from == 3 and rep.policy == "double_buffered"
    _assert_identical(ref, _results(resumed), "cross-policy")


def test_resume_rejects_warmup(tmp_path):
    mgr = CheckpointManager(tmp_path / "ckpt")
    eng = _engine("blocking")
    eng.run(_source(), n_batches=2, seed=SEED, checkpoint_every=1,
            checkpoint_manager=mgr)
    eng2 = _engine("blocking")
    with pytest.raises(ValueError, match="warmup_items must be 0"):
        eng2.run(_source(), n_batches=N_BATCHES, seed=SEED, warmup_items=1,
                 checkpoint_manager=mgr, resume=True)


def test_checkpointing_requires_manager_and_accounting():
    eng = _engine("blocking")
    with pytest.raises(ValueError, match="checkpoint_manager"):
        eng.run(_source(), n_batches=2, checkpoint_every=1)


def test_resume_rejects_unknown_sink_state(tmp_path):
    mgr = CheckpointManager(tmp_path / "ckpt")
    eng = _engine("blocking")  # stats + matrices
    eng.run(_source(), n_batches=2, seed=SEED, checkpoint_every=1,
            checkpoint_manager=mgr)
    lean = TrafficEngine(_cfg(), policy="blocking",
                         sinks=[StatsAccumulator()])
    with pytest.raises(ValueError, match="not attached"):
        lean.run(_source(), n_batches=N_BATCHES, seed=SEED,
                 checkpoint_manager=mgr, resume=True)


# ---------------------------------------------------------------------------
# resume cursor plumbing
# ---------------------------------------------------------------------------
def test_fast_forward_device_source_is_exact():
    full = list(_source(4))
    moved = fast_forward(_source(4), 2)
    assert isinstance(moved, DeviceSyntheticSource)
    rest = list(moved)
    assert len(rest) == 2
    for a, b in zip(rest, full[2:]):
        np.testing.assert_array_equal(a, b)
    # generic sources get the skipping wrapper instead
    wrapped = fast_forward(SkippingSource(inner=_source(4), skip=0), 2)
    assert isinstance(wrapped, SkippingSource)
    for a, b in zip(wrapped, full[2:]):
        np.testing.assert_array_equal(a, b)
    # skipping past the end is an empty stream, not an error
    assert list(fast_forward(_source(2), 5)) == []


# ---------------------------------------------------------------------------
# portable serialization + manager hygiene
# ---------------------------------------------------------------------------
def test_portable_roundtrip(tmp_path):
    tree = {
        "ints": 7,
        "floats": 0.25,
        "strings": "hello",
        "flags": True,
        "nothing": None,
        "nested": {"list": [1, "two", np.arange(6, dtype=np.uint32)],
                   "tuple": (np.float32(1.5), [{"deep": np.eye(2)}])},
    }
    p = tmp_path / "x.rpck"
    save_pytree(tree, p, portable=True, meta={"who": "test"})
    back, meta = load_pytree(p)
    assert meta == {"who": "test"}
    assert back["ints"] == 7 and isinstance(back["ints"], int)
    assert back["floats"] == 0.25
    assert back["strings"] == "hello" and back["flags"] is True
    assert back["nothing"] is None
    lst = back["nested"]["list"]
    assert lst[0] == 1 and lst[1] == "two"
    np.testing.assert_array_equal(lst[2], np.arange(6, dtype=np.uint32))
    assert lst[2].dtype == np.uint32
    tup = back["nested"]["tuple"]
    assert isinstance(tup, tuple)
    np.testing.assert_array_equal(tup[1][0]["deep"], np.eye(2))


def test_portable_rejects_non_str_keys(tmp_path):
    with pytest.raises(TypeError, match="str dict keys"):
        save_pytree({1: "x"}, tmp_path / "x.rpck", portable=True)


def test_manager_portable_restore_without_template(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"sinks": {"stats": {"rows": [np.arange(3, dtype=np.uint32)]}},
             "batches_done": 4}
    mgr.save(4, state, meta={"policy": "blocking"}, portable=True)
    back, meta = mgr.restore(None)  # no `like` template needed
    assert meta["step"] == 4 and meta["policy"] == "blocking"
    assert back["batches_done"] == 4
    np.testing.assert_array_equal(back["sinks"]["stats"]["rows"][0],
                                  np.arange(3, dtype=np.uint32))


def test_direct_save_races_async_save_safely(tmp_path):
    """The satellite fix: save() takes the manager lock, so a direct save
    racing an in-flight async save cannot interleave with its tmp-write/
    rename/gc sequence.  Hammer the pair and check every surviving
    checkpoint loads cleanly."""
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": np.arange(2048, dtype=np.float64)}
    stop = threading.Event()
    errors = []

    def direct_saver():
        step = 1000
        while not stop.is_set():
            try:
                mgr.save(step, state, portable=True)
            except Exception as e:  # noqa: BLE001 - the assertion payload
                errors.append(e)
                return
            step += 1

    t = threading.Thread(target=direct_saver)
    t.start()
    try:
        for step in range(1, 20):
            mgr.save_async(step, state, portable=True)
        mgr.wait()
    finally:
        stop.set()
        t.join()
    assert not errors
    for step in mgr.steps():
        back, _ = mgr.restore(None, step=step)
        np.testing.assert_array_equal(back["w"], state["w"])


def test_stale_tmp_cleaned_at_discovery(tmp_path):
    """The satellite fix: a crashed sibling's half-written tmp file is
    removed when a new manager takes over the directory (a tmp written
    AFTER construction — a live save — is untouched; see
    test_checkpoint_crash_safety)."""
    stale = tmp_path / "ckpt_0000000007.tmp"
    stale.write_bytes(b"half-written garbage")
    other = tmp_path / "unrelated.tmp"
    other.write_bytes(b"not ours")
    mgr = CheckpointManager(tmp_path)
    assert not stale.exists()
    assert other.exists()  # only our own naming is touched
    assert mgr.steps() == []


def test_save_async_waits_for_previous(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=10)
    state = {"w": np.arange(64, dtype=np.float32)}
    for step in (1, 2, 3):
        mgr.save_async(step, state, portable=True)
    mgr.wait()
    assert mgr.steps() == [1, 2, 3]
    # a second wait is a no-op, not an error
    mgr.wait()


def test_checkpoint_file_is_atomic_under_kill(tmp_path):
    """Simulated death mid-save: the tmp never shadows a finished
    checkpoint, and the latest complete file stays restorable."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": np.ones(4)}, portable=True)
    # a save that died after tmp-write but before rename
    (tmp_path / "ckpt_0000000002.tmp").write_bytes(b"RPCK\x00truncated")
    assert mgr.latest_step() == 1
    back, meta = mgr.restore(None)
    assert meta["step"] == 1
    np.testing.assert_array_equal(back["w"], np.ones(4))


def test_manager_rejects_keep_lt_1(tmp_path):
    with pytest.raises(ValueError, match="keep must be >= 1"):
        CheckpointManager(tmp_path, keep=0)


def test_keep_pruning_is_exactly_keep_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    for step in range(1, 7):
        mgr.save(step, {"w": np.arange(4)}, portable=True)
    assert mgr.steps() == [4, 5, 6]
    leftovers = [p.name for p in tmp_path.iterdir()
                 if p.name.startswith("ckpt_")]
    assert len(leftovers) == 3


def test_wait_reraises_async_save_failure(tmp_path):
    """The satellite fix: a failed background save must surface at
    wait(), not vanish — a daemon that never observes the failure would
    run forever with no durable checkpoints."""
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(1, {2: "non-str key"}, portable=True)
    with pytest.raises(TypeError, match="str dict keys"):
        mgr.wait()
    # the error is consumed: the manager keeps working afterwards
    mgr.wait()
    mgr.save_async(2, {"ok": np.ones(2)}, portable=True)
    mgr.wait()
    assert mgr.steps() == [2]


def test_async_save_failure_surfaces_at_next_save_async(tmp_path):
    """save_async's one-in-flight handoff waits on the previous worker,
    so the previous failure re-raises there (and the new save is not
    started on top of an unobserved error)."""
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(1, {2: "non-str key"}, portable=True)
    with pytest.raises(TypeError, match="str dict keys"):
        mgr.save_async(2, {"ok": np.ones(2)}, portable=True)
    mgr.wait()  # error already consumed
    assert mgr.steps() == []


def test_concurrent_save_async_leaks_no_writer_threads(tmp_path):
    """The satellite fix: concurrent save_async callers serialize their
    handoff — every writer thread is joined (the conftest thread-leak
    sanitizer backstops this) and every completed save is restorable."""
    mgr = CheckpointManager(tmp_path, keep=32)
    state = {"w": np.arange(1024, dtype=np.float64)}
    errors = []

    def caller(step):
        try:
            mgr.save_async(step, state, portable=True)
        except Exception as e:  # noqa: BLE001 - the assertion payload
            errors.append(e)

    threads = [threading.Thread(target=caller, args=(s,))
               for s in range(1, 9)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mgr.wait()
    assert not errors
    assert not [t for t in threading.enumerate()
                if t.name.startswith("repro-ckpt-writer")]
    steps = mgr.steps()
    assert steps  # at least the last handoff's save landed
    for step in steps:
        back, _ = mgr.restore(None, step=step)
        np.testing.assert_array_equal(back["w"], state["w"])
