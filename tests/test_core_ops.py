"""GraphBLAS op set vs dense numpy oracles + semiring properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import from_dense, ops, types


def rand_dense(rng, n=24, density=0.2, lo=1, hi=9):
    d = rng.integers(lo, hi, (n, n)).astype(np.int32)
    mask = rng.random((n, n)) < density
    return (d * mask).astype(np.int32)


def as_np(A, n):
    r, c, v = A.entries()
    out = np.zeros((n, n), np.int64)
    out[r.astype(int), c.astype(int)] = v
    return out


def test_ewise_add_union(rng):
    a, b = rand_dense(rng), rand_dense(rng)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C, ovf = ops.ewise_add(A, B)
    assert int(ovf) == 0
    assert np.array_equal(as_np(C, 24), a.astype(np.int64) + b)


def test_ewise_add_noncommutative_op(rng):
    a, b = rand_dense(rng), rand_dense(rng)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C, _ = ops.ewise_add(A, B, types.FIRST)
    # where both present: takes A's value; union elsewhere
    both = (a != 0) & (b != 0)
    ref = (a + b).astype(np.int64)
    ref[both] = a[both]
    assert np.array_equal(as_np(C, 24), ref)


def test_ewise_add_overflow_accounting(rng):
    a, b = rand_dense(rng, density=0.5), rand_dense(rng, density=0.5)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    cap = 10
    C, ovf = ops.ewise_add(A, B, out_capacity=cap)
    union = ((a != 0) | (b != 0)).sum()
    assert int(ovf) == max(0, union - cap)
    assert int(C.nnz) == min(cap, union)


def test_ewise_mult_intersection(rng):
    a, b = rand_dense(rng, density=0.4), rand_dense(rng, density=0.4)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C, _ = ops.ewise_mult(A, B, out_capacity=24 * 24)
    assert np.array_equal(as_np(C, 24), a.astype(np.int64) * b)


def test_mxm_plus_times(rng):
    a, b = rand_dense(rng, 16, 0.3), rand_dense(rng, 16, 0.3)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C, ovf = ops.mxm(A, B, types.PLUS_TIMES, expansion_capacity=4096)
    assert int(ovf) == 0
    assert np.array_equal(as_np(C, 16), a.astype(np.int64) @ b)


def test_mxm_min_plus(rng):
    # shortest-path relaxation semiring over the pattern
    inf = 10 ** 6
    a = rand_dense(rng, 12, 0.4, 1, 9).astype(np.int32)
    b = rand_dense(rng, 12, 0.4, 1, 9).astype(np.int32)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C, _ = ops.mxm(A, B, types.MIN_PLUS, expansion_capacity=4096)
    ad = np.where(a == 0, inf, a).astype(np.int64)
    bd = np.where(b == 0, inf, b).astype(np.int64)
    ref = (ad[:, :, None] + bd[None, :, :]).min(axis=1)
    got = as_np(C, 12)
    mask = got != 0  # only compare where structurally present
    assert (got[mask] == ref[mask]).all()


def test_mxm_overflow_reported(rng):
    a = (rand_dense(rng, 16, 0.9) > 0).astype(np.int32)
    A = from_dense(jnp.asarray(a))
    C, ovf = ops.mxm(A, A, expansion_capacity=64)
    assert int(ovf) > 0  # dense-ish square blows a tiny expansion budget


def test_reductions(rng):
    a = rand_dense(rng)
    A = from_dense(jnp.asarray(a))
    assert np.array_equal(
        np.asarray(ops.reduce_rows(A).to_dense()), a.sum(1)
    )
    assert np.array_equal(
        np.asarray(ops.reduce_cols(A).to_dense()), a.sum(0)
    )
    assert int(ops.reduce_scalar(A)) == a.sum()
    assert int(ops.reduce_scalar(A, types.MAX_MONOID)) == a.max()
    fanout = ops.reduce_rows(ops.apply(A, types.ONE))
    assert np.array_equal(
        np.asarray(fanout.to_dense()), (a > 0).sum(1)
    )


def test_transpose_select_extract(rng):
    a = rand_dense(rng)
    A = from_dense(jnp.asarray(a))
    assert np.array_equal(as_np(ops.transpose(A), 24), a.T)
    # select: keep entries > 4
    S = ops.select(A, lambda r, c, v: v > 4)
    ref = np.where(a > 4, a, 0)
    assert np.array_equal(as_np(S, 24), ref)
    # extract block [4, 12) x [8, 20)
    E = ops.extract_block(A, 4, 12, 8, 20)
    r, c, v = E.entries()
    got = np.zeros((8, 12), np.int64)
    got[r.astype(int), c.astype(int)] = v
    assert np.array_equal(got, a[4:12, 8:20])


def test_with_capacity_roundtrip(rng):
    a = rand_dense(rng)
    A = from_dense(jnp.asarray(a))
    B, ovf = ops.with_capacity(A, int(A.nnz))
    assert int(ovf) == 0
    assert np.array_equal(as_np(B, 24), a)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 40))
def test_ewise_add_commutative_plus(seed, n):
    rng = np.random.default_rng(seed)
    a = rand_dense(rng, 16, 0.3)
    b = rand_dense(rng, 16, 0.3)
    A, B = from_dense(jnp.asarray(a)), from_dense(jnp.asarray(b))
    C1, _ = ops.ewise_add(A, B)
    C2, _ = ops.ewise_add(B, A)
    assert np.array_equal(as_np(C1, 16), as_np(C2, 16))


def test_spmm_sddmm_vs_dense(rng):
    a = rand_dense(rng, 32, 0.2).astype(np.float32)
    A = from_dense(jnp.asarray(a))
    X = rng.standard_normal((32, 7)).astype(np.float32)
    out = ops.spmm_dense(A, jnp.asarray(X), num_rows=32)
    np.testing.assert_allclose(np.asarray(out), a @ X, rtol=1e-4, atol=1e-4)

    U = rng.standard_normal((32, 5)).astype(np.float32)
    V = rng.standard_normal((32, 5)).astype(np.float32)
    e = ops.sddmm(A.rows, A.cols, jnp.asarray(U), jnp.asarray(V), A.nnz)
    r, c, _ = A.entries()
    ref = np.einsum("ed,ed->e", U[r.astype(int)], V[c.astype(int)])
    np.testing.assert_allclose(np.asarray(e)[: len(ref)], ref, rtol=1e-4,
                               atol=1e-4)
