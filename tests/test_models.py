"""Model substrate: transformer equivalences, MoE dispatch exactness,
GNN vs dense-adjacency oracles, recsys behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn as gnn_mod
from repro.models import layers
from repro.models.moe import MoEConfig, expert_capacity, init_moe, moe_apply
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward,
    init_transformer,
    lm_loss,
    prefill,
)

CFG = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=300, dtype="float32", attn_kv_block=8,
)


@pytest.fixture(scope="module")
def params():
    return init_transformer(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 300)


def test_vocab_padding(params):
    # 300 % 16 != 0 -> padded to 304; loss must ignore padded columns
    assert params["embed"].shape[0] == 304
    assert params["lm_head"].shape[1] == 304


def test_chunked_equals_full_attention(params, toks):
    full_cfg = dataclasses.replace(CFG, attn_kv_block=10 ** 9)
    h1, _ = forward(params, toks, CFG)
    h2, _ = forward(params, toks, full_cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_unrolled_equals_looped(params, toks):
    u_cfg = dataclasses.replace(CFG, unroll_scans=True)
    l1, m1 = lm_loss(params, toks, toks, CFG)
    l2, m2 = lm_loss(params, toks, toks, u_cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_decode_matches_forward(params, toks):
    logits_pf, cache, clen = prefill(params, toks, CFG)
    cache = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
        cache,
    )
    nxt = jnp.argmax(logits_pf, -1)[:, None].astype(jnp.int32)
    logits_dec, _ = decode_step(params, nxt, cache, clen, CFG)
    ext = jnp.concatenate([toks, nxt], axis=1)
    h, _ = forward(params, ext, CFG)
    ref = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)
    ref = jnp.where(jnp.arange(CFG.padded_vocab) < CFG.vocab_size, ref,
                    -1e30)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_grads_finite(params, toks):
    g = jax.grad(lambda p: lm_loss(p, toks, toks, CFG)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# MoE: sort-based dispatch must equal the dense (every-expert) computation
# ---------------------------------------------------------------------------
def _dense_moe_ref(params, x, cfg: MoEConfig):
    t, d = x.shape
    logits = (x @ params["router"]).astype(np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    out = np.zeros((t, d), np.float32)
    for tok in range(t):
        for k in range(cfg.top_k):
            e = int(gi[tok, k])
            h = x[tok]
            g = jax.nn.silu(h @ params["w_gate"][e])
            u = h @ params["w_up"][e]
            y = (g * u) @ params["w_down"][e]
            out[tok] += float(gv[tok, k]) * np.asarray(y)
    if cfg.d_ff_shared:
        out += np.asarray(layers.gated_mlp(params["shared"], jnp.asarray(x)))
    return out


def test_moe_dispatch_exact(rng):
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, d_ff_shared=64,
                    capacity_factor=8.0)  # big capacity: no drops
    params = init_moe(jax.random.PRNGKey(0), 48, cfg)
    x = jnp.asarray(rng.standard_normal((1, 24, 48)).astype(np.float32))
    out, aux = moe_apply(params, x, cfg)
    assert float(aux["dropped_fraction"]) == 0.0
    ref = _dense_moe_ref(params, np.asarray(x[0]), cfg)
    np.testing.assert_allclose(np.asarray(out[0]), ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_counted(rng):
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=0.25)
    params = init_moe(jax.random.PRNGKey(0), 32, cfg)
    x = jnp.asarray(rng.standard_normal((1, 64, 32)).astype(np.float32))
    out, aux = moe_apply(params, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_expert_capacity_rounding():
    assert expert_capacity(4096, MoEConfig(60, 4, 1408)) % 8 == 0


# ---------------------------------------------------------------------------
# GNN oracles on dense adjacency
# ---------------------------------------------------------------------------
def test_gcn_matches_dense(rng):
    n, d, c = 40, 12, 5
    cfg = gnn_mod.GNNConfig(name="g", arch="gcn", n_layers=2, d_in=d,
                            d_hidden=16, n_classes=c)
    params = gnn_mod.init_gcn(jax.random.PRNGKey(0), cfg)
    # symmetric graph with self-loops
    src0 = rng.integers(0, n, 80).astype(np.int32)
    dst0 = rng.integers(0, n, 80).astype(np.int32)
    from repro.data.graphs import symmetrize_with_self_loops

    src, dst = symmetrize_with_self_loops(src0, dst0, n)
    x = rng.standard_normal((n, d)).astype(np.float32)
    out = gnn_mod.gcn_apply(
        params, jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
        jnp.int32(n), jnp.int32(len(src)), cfg,
    )
    # dense reference
    A = np.zeros((n, n), np.float32)
    A[src, dst] = 1.0
    deg = A.sum(0)
    Ahat = A / np.sqrt(deg)[:, None] / np.sqrt(deg)[None, :]
    h = x
    for i, layer in enumerate(params["layers"]):
        h = Ahat.T @ h @ np.asarray(layer["w"]) + np.asarray(layer["b"])
        if i < len(params["layers"]) - 1:
            h = np.maximum(h, 0)
    np.testing.assert_allclose(np.asarray(out), h, rtol=2e-3, atol=2e-3)


def test_gat_edge_softmax_normalized(rng):
    n, d = 30, 8
    cfg = gnn_mod.GNNConfig(name="g", arch="gat", n_layers=1, d_in=d,
                            d_hidden=4, n_classes=4, n_heads=2)
    params = gnn_mod.init_gat(jax.random.PRNGKey(0), cfg)
    src = rng.integers(0, n, 100).astype(np.int32)
    dst = rng.integers(0, n, 100).astype(np.int32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    out = gnn_mod.gat_apply(
        params, jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
        jnp.int32(n), jnp.int32(100), cfg,
    )
    assert out.shape == (n, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_egnn_equivariance(rng):
    """Rotating input coordinates must rotate coordinate outputs and leave
    feature outputs unchanged (E(3) equivariance)."""
    n, e, d = 20, 60, 8
    cfg = gnn_mod.GNNConfig(name="g", arch="egnn", n_layers=2, d_in=d,
                            d_hidden=16, n_classes=4)
    params = gnn_mod.init_egnn(jax.random.PRNGKey(0), cfg)
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    pos = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    # random rotation
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    R = jnp.asarray(q.astype(np.float32))
    h1, p1 = gnn_mod.egnn_apply(params, x, pos, src, dst, jnp.int32(n),
                                jnp.int32(e), cfg)
    h2, p2 = gnn_mod.egnn_apply(params, x, pos @ R.T, src, dst, jnp.int32(n),
                                jnp.int32(e), cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(p1 @ R.T), np.asarray(p2),
                               rtol=2e-3, atol=2e-3)


def test_pna_aggregators_vs_numpy(rng):
    n, e, d = 25, 70, 6
    cfg = gnnc = gnn_mod.GNNConfig(name="g", arch="pna", n_layers=1, d_in=d,
                                   d_hidden=5, n_classes=3)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    params = gnn_mod.init_pna(jax.random.PRNGKey(0), cfg)
    out = gnn_mod.pna_apply(
        params, jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
        jnp.int32(n), jnp.int32(e), cfg,
    )
    assert out.shape == (n, 3)
    assert np.isfinite(np.asarray(out)).all()


def test_recsys_score_consistency(rng):
    from repro.models.recsys import (
        TwoTowerConfig, init_two_tower, retrieve_topk, score_pairs,
    )

    cfg = TwoTowerConfig(name="t", embed_dim=8, tower_mlp=(32, 16),
                         n_user_fields=2, n_item_fields=2, history_len=4,
                         user_vocab=100, item_vocab=100)
    params = init_two_tower(jax.random.PRNGKey(0), cfg)
    batch = {
        "user_fields": jnp.asarray([[1, 2]], jnp.int32),
        "history": jnp.asarray([[3, 4, 0, 0]], jnp.int32),
        "history_len": jnp.asarray([2], jnp.int32),
    }
    cands = jnp.asarray(rng.integers(0, 100, (50, 2)).astype(np.int32))
    vals, idx = retrieve_topk(params, batch, cands, cfg, k=5)
    # scoring the top candidate as a pair gives the same value
    top = cands[idx[0]][None]
    s = score_pairs(params, {**batch, "item_fields": top}, cfg)
    np.testing.assert_allclose(float(s[0]), float(vals[0]), rtol=1e-4)
    # top-k really is sorted descending
    assert (np.diff(np.asarray(vals)) <= 1e-6).all()
