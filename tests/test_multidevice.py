"""Multi-device correctness (8 placeholder CPU devices via subprocess —
XLA locks the device count at first init, so these run out-of-process):

  * expert-parallel shard_map MoE == baseline dispatch, elementwise;
  * exact distributed ingest merge == direct single-build analytics
    across a real 2x4 mesh (all_to_all path included).
"""

import subprocess
import sys
import textwrap

import pytest


def _run(code: str):
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             # pin the child to CPU: these tests are about the 8 forced
             # host devices, and without the pin jax may pick a TPU
             # plugin whose init wedges on boxes with no usable TPU
             "JAX_PLATFORMS": "cpu",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )


@pytest.mark.slow
def test_moe_ep_matches_baseline_8dev():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.moe import MoEConfig, init_moe, moe_apply, moe_apply_ep
        from repro.launch.mesh import ambient_mesh, make_mesh_from_plan

        mesh = make_mesh_from_plan((2, 4), ("data", "model"))
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, d_ff_shared=64,
                        capacity_factor=8.0, n_experts_padded=8)
        cfg_ep = dataclasses.replace(cfg, expert_shard_map=True,
                                     dp_axes=("data",))
        params = init_moe(jax.random.PRNGKey(0), 48, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 48), jnp.float32)
        with ambient_mesh(mesh):
            specs = {"router": P(), "w_gate": P("model", None, None),
                     "w_up": P("model", None, None),
                     "w_down": P("model", None, None),
                     "shared": {"w_gate": P(None, "model"),
                                "w_up": P(None, "model"),
                                "w_down": P("model", None)}}
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                              is_leaf=lambda v: isinstance(v, P))
            ps = jax.device_put(params, sh)
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            o1, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg))(ps, xs)
            o2, _ = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg_ep))(ps, xs)
            np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                       rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_exact_ingest_8dev():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import analytics
        from repro.core.build import matrix_build
        from repro.core.window import WindowConfig
        from repro.launch.ingest import make_exact_ingest_step
        from repro.launch.mesh import ambient_mesh, make_mesh_from_plan

        mesh = make_mesh_from_plan((2, 4), ("data", "model"))
        cfg = WindowConfig(window_log2=7, windows_per_batch=1,
                           cap_max_log2=9, anonymization="none")
        step = jax.jit(make_exact_ingest_step(mesh, cfg))
        rng = np.random.default_rng(0)
        w = rng.integers(0, 1 << 32, (8, cfg.window_size, 2),
                         dtype=np.uint32)
        with ambient_mesh(mesh):
            out = jax.block_until_ready(step(jnp.asarray(w)))
        flat = w.reshape(-1, 2)
        A = matrix_build(jnp.asarray(flat[:, 0]), jnp.asarray(flat[:, 1]))
        ref = analytics.window_stats(A)
        assert int(out["unique_links"]) == int(ref["unique_links"])
        assert int(out["unique_sources"]) == int(ref["unique_sources"])
        assert int(out["valid_packets"]) == flat.shape[0]
        assert int(out["max_source_fanout"]) == int(ref["max_source_fanout"])
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr
