"""Anonymization properties: bijectivity, prefix preservation, structure
preservation of the traffic matrix."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import anonymize, matrix_build

u32 = st.integers(0, 2 ** 32 - 1)


@given(st.lists(u32, min_size=1, max_size=256), st.integers(0, 2 ** 31))
def test_feistel_bijective(addrs, key):
    a = jnp.asarray(np.array(addrs, np.uint32))
    anon = anonymize.feistel_permute(a, key)
    back = anonymize.feistel_unpermute(anon, key)
    assert np.array_equal(np.asarray(back), np.asarray(a))


@given(st.lists(u32, min_size=1, max_size=256), st.integers(0, 2 ** 31))
def test_cryptopan_bijective(addrs, key):
    a = jnp.asarray(np.array(addrs, np.uint32))
    anon = anonymize.cryptopan(a, key)
    back = anonymize.cryptopan_inverse(anon, key)
    assert np.array_equal(np.asarray(back), np.asarray(a))


@given(u32, st.integers(0, 31), st.integers(0, 2 ** 31))
def test_cryptopan_prefix_preserving(addr, flip_bit, key):
    """Two addresses differing first at bit k share exactly the top-k
    anonymized prefix."""
    a1 = np.uint32(addr)
    a2 = np.uint32(addr ^ (1 << flip_bit))
    c1, c2 = np.asarray(
        anonymize.cryptopan(jnp.asarray(np.array([a1, a2])), key)
    )
    # common input prefix length
    diff = int(a1 ^ a2)
    k = 32 - diff.bit_length()
    out_diff = int(c1 ^ c2)
    out_k = 32 - out_diff.bit_length()
    assert out_k == k


def test_distinctness_preserved(rng):
    """Anonymized traffic matrix has identical structure statistics."""
    pkts = rng.integers(0, 1 << 16, (2048, 2)).astype(np.uint32)
    anon = anonymize.anonymize_packets(jnp.asarray(pkts), 7, "feistel")
    A = matrix_build(jnp.asarray(pkts[:, 0]), jnp.asarray(pkts[:, 1]))
    B = matrix_build(anon[:, 0], anon[:, 1])
    assert int(A.nnz) == int(B.nnz)
    av = np.sort(np.asarray(A.masked_vals()))
    bv = np.sort(np.asarray(B.masked_vals()))
    assert np.array_equal(av, bv)  # multiset of link counts identical


def test_keys_differ(rng):
    addrs = jnp.asarray(rng.integers(0, 1 << 32, 512, dtype=np.uint32))
    a1 = np.asarray(anonymize.feistel_permute(addrs, 1))
    a2 = np.asarray(anonymize.feistel_permute(addrs, 2))
    assert (a1 != a2).mean() > 0.99
