"""Optimizers, schedules, gradient transforms (clipping, compression)."""

from repro.optim.optimizers import adamw, sgd, OptState, Optimizer  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_warmup,
    linear_warmup,
)
from repro.optim.grad import (  # noqa: F401
    clip_by_global_norm,
    global_norm,
    int8_compress,
    int8_decompress,
)
