"""Gradient transforms: clipping, accumulation, int8 error-feedback
compression.

The compression pair targets the slow cross-pod (DCN) axis: gradients are
quantized to int8 with a per-tensor scale before the pod all-reduce and the
quantization error is fed back into the next step's gradient (error-feedback
SGD, Seide et al. / Karimireddy et al.), which keeps convergence unbiased
in practice. 4x fewer bytes on the pod axis = 4x lower collective term for
DP-over-DCN (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def int8_compress(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x -> (int8 payload, fp32 scale). Symmetric per-tensor quantization."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decompress(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Params):
    """Quantize every leaf; returns (payload tree, scale tree)."""
    qs = jax.tree.map(int8_compress, grads)
    payload = jax.tree.map(lambda t: t[0], qs,
                           is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    return payload, scales


def error_feedback_compress(grads: Params, error: Params):
    """(grads + error) -> int8 payload; returns payload, scales, new error.

    new_error = (g + e) - dequant(quant(g + e)); feed into next step.
    """
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, error)
    payload, scales = compress_tree(corrected)
    dq = jax.tree.map(int8_decompress, payload, scales)
    new_error = jax.tree.map(lambda c, d: c - d, corrected, dq)
    return payload, scales, new_error


def init_error_state(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
