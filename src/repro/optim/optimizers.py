"""Optimizers as pure (init, update) pairs over parameter pytrees.

Self-contained (no optax dependency): AdamW and SGD+momentum, both
shard-transparent — optimizer state inherits parameter sharding, so ZeRO-1
style sharded optimizer state falls out of pjit by giving the state the same
(or more sharded) PartitionSpecs as the params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment / momentum
    nu: Any          # second moment (None for sgd)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState, jax.Array], tuple]
    # update(grads, params, state, lr) -> (new_params, new_state)


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params: Params) -> OptState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return OptState(step=jnp.int32(0), mu=zeros,
                        nu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, params, state: OptState, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads
        )
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (
                p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
            ).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params: Params) -> OptState:
        return OptState(
            step=jnp.int32(0),
            mu=jax.tree.map(jnp.zeros_like, params),
            nu=None,
        )

    def update(grads, params, state: OptState, lr):
        mu = jax.tree.map(
            lambda m, g: momentum * m + g, state.mu, grads
        )
        if nesterov:
            eff = jax.tree.map(lambda g, m: g + momentum * m, grads, mu)
        else:
            eff = mu
        new_params = jax.tree.map(
            lambda p, e: (p - lr * e).astype(p.dtype), params, eff
        )
        return new_params, OptState(step=state.step + 1, mu=mu, nu=None)

    return Optimizer(init=init, update=update)
