"""Bounded-queue background prefetch: the one producer/consumer primitive.

This is the double-buffer discipline every previous copy of the pipeline
hand-rolled (``core.stream``'s producer thread, ``data.pipeline``'s
``Prefetcher``): a worker thread pulls items from an iterable, optionally
transforms them (device_put, shard placement, decompression — the "IO"
stage), and feeds a depth-bounded queue.  The bounded queue is the
backpressure, exactly like the DPU's receive queues: when the device falls
behind, the producer blocks instead of buffering unboundedly.

Exceptions raised by the source or the transform are re-raised in the
consumer thread, after all successfully produced items are drained.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Iterator

_STOP = object()


class BoundedPrefetcher:
    """Background-thread prefetch of an iterable, depth-bounded.

    Attributes:
      produce_s: cumulative seconds the worker spent in ``transform`` —
        the pipeline's IO-side cost, reported in ``EngineReport.produce_s``.
    """

    def __init__(self, it: Iterable, depth: int = 2,
                 transform: Callable | None = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self.produce_s = 0.0

        def worker():
            try:
                for item in it:
                    if transform is not None:
                        t0 = time.perf_counter()
                        item = transform(item)
                        self.produce_s += time.perf_counter() - t0
                    self._q.put(item)
            except BaseException as e:  # surface in consumer
                self._err = e
            finally:
                self._q.put(_STOP)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is _STOP:
            self._thread.join()
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
