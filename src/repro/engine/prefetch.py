"""Bounded multi-worker prefetch: the one producer/consumer primitive.

This is the double-buffer discipline every previous copy of the pipeline
hand-rolled (``core.stream``'s producer thread, ``data.pipeline``'s
``Prefetcher``), generalized to N workers: worker threads pull items from an
iterable, optionally transform them (device_put, decode, decompression —
the "IO" stage), and feed a depth-bounded reorder buffer.  The depth bound
is the backpressure, exactly like the DPU's receive queues: when the device
falls behind, producers park instead of buffering unboundedly.

Ordering contract: items are delivered to the consumer in *source order*
regardless of worker count or per-item transform latency.  Source pulls are
serialized under an iterator lock and stamped with a sequence number; each
worker transforms its item concurrently and files the result under its
sequence number; the consumer only ever takes the next sequence number in
line.  With ``workers=1`` this degenerates to the classic single-producer
double buffer.

Exceptions raised by the source or a transform are re-raised in the
consumer thread, after all items sequenced *before* the failure are
drained (later items, even if already transformed, are discarded).

Cancellation is condition-driven — no polling loops.  A consumer that
stops early (breaks out of its loop, or a pipeline that dies mid-stream)
calls ``close()``: parked workers and a parked consumer wake immediately,
buffered items are dropped, and the threads are joined.  A worker that
cannot be joined (e.g. a source blocked in foreign code) is reported with
a ``RuntimeWarning`` naming the thread instead of leaking silently.
``BoundedPrefetcher`` is also a context manager (``__exit__`` closes);
closing an exhausted or already-closed prefetcher is a no-op.
"""

from __future__ import annotations

import threading
import time
import warnings
from typing import Callable, Iterable, Iterator

from repro.distributed.fault import (
    HeartbeatMonitor,
    PolicyDecision,
    StragglerPolicy,
)


class WorkerKilled(BaseException):
    """Simulated death of the thread that raised it.

    Deliberately a ``BaseException``: every ``except BaseException`` handler
    in the produce path explicitly re-raises it first, so it unwinds the
    worker thread instead of being recorded as an ordinary stream failure —
    which is exactly what a real ``pthread_kill``/OOM would look like.
    Raised by fault injection (``engine.faults``); never raise it yourself
    unless you want the worker dead.
    """


class WorkerDiedError(RuntimeError):
    """A prefetch worker died without delivering the item it had reserved.

    Recorded by the dead worker's last-rites handler at the lost item's
    sequence number, so the consumer drains every earlier item and then
    sees this instead of hanging forever on a sequence gap.
    """


class BoundedPrefetcher:
    """Background prefetch of an iterable: N workers, in-order delivery.

    Args:
      it: the source iterable (pulls are serialized, so any iterator works).
      depth: max items beyond the consumer's position that may be reserved
        at once (buffered + in transform).  The effective bound is
        ``max(depth, workers)`` so every worker can hold one item.
      transform: optional per-item function applied on the worker threads —
        this is the part N workers parallelize.
      untimed_items: leading items excluded from ``produce_s`` (warmup), the
        same way the consumer excludes them from elapsed/process accounting.
      workers: number of producer threads (>= 1).

    ``produce_s`` reports cumulative transform seconds — the pipeline's
    IO-side cost, reported in ``EngineReport.produce_s``.  It is snapshotted
    under the prefetcher lock and *includes in-progress transforms*, so a
    reader observing a run that died mid-stream still sees the final
    in-flight transform's time.
    """

    def __init__(self, it: Iterable, depth: int = 2,
                 transform: Callable | None = None,
                 untimed_items: int = 0, workers: int = 1,
                 monitor: HeartbeatMonitor | None = None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self._it = iter(it)
        self._transform = transform
        self._untimed = untimed_items
        self._depth = max(depth, workers)
        # The one condition variable ordering ALL shared state below (the
        # name is load-bearing twice over: repro.analysis's
        # thread-shared-state lint recognizes lock-named context managers,
        # and a Condition *is* a lock with wait/notify on top).
        self._lock = threading.Condition()
        # Serializes source pulls so sequence order == iteration order.
        # Held across next(it) WITHOUT holding _lock, so a source blocked
        # in its own body never wedges close() or the consumer.
        self._it_lock = threading.Lock()
        self._buf: dict[int, object] = {}  # seq -> item awaiting delivery
        self._next_seq = 0       # next sequence number to reserve
        self._next_out = 0       # next sequence number the consumer takes
        self._exhausted_at: int | None = None  # seq where source ended
        self._err: BaseException | None = None
        self._err_seq: int | None = None  # earliest failed sequence number
        self._closed = False
        self._produce_s = 0.0
        self._active: dict[str, float] = {}  # thread -> transform start t
        self._working: dict[str, int] = {}   # thread -> reserved, undelivered seq
        # one "host" per worker: beats on every delivered item, marked dead
        # by the last-rites handler — StragglerPolicy then reports evict
        self.monitor = monitor if monitor is not None else (
            HeartbeatMonitor(workers))
        self._straggler = StragglerPolicy(self.monitor)
        # the name prefix is load-bearing: the thread-leak fixture in
        # tests/conftest.py fails any test that leaves a repro-* thread
        # alive, which is what pins the close() discipline
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"repro-prefetch-worker-{i}")
            for i in range(workers)
        ]
        self._worker_idx = {t.name: i for i, t in enumerate(self._threads)}
        for t in self._threads:
            t.start()

    # -- worker side --------------------------------------------------------

    def _pull(self, me: str):
        """Reserve the next sequence number and pull its item from the
        source.  Returns ``(seq, item)`` or None when there is nothing more
        for this worker to do (closed / failed / exhausted)."""
        with self._it_lock:
            with self._lock:
                while (not self._closed and self._err is None
                       and self._exhausted_at is None
                       and self._next_seq - self._next_out >= self._depth):
                    self._lock.wait()
                if (self._closed or self._err is not None
                        or self._exhausted_at is not None):
                    return None
                seq = self._next_seq
                self._next_seq = seq + 1
                self._working[me] = seq
            # _lock released, _it_lock still held: pulls stay in seq order
            # and a blocking source only ever blocks other *pulls*
            try:
                item = next(self._it)
            except StopIteration:
                with self._lock:
                    self._working.pop(me, None)
                    self._exhausted_at = seq
                    self._lock.notify_all()
                return None
            except WorkerKilled:
                # the reserved seq stays in _working: last rites record it
                raise
            except BaseException as e:  # surface in consumer
                with self._lock:
                    self._working.pop(me, None)
                    self._record_failure(e, seq)
                return None
        return seq, item

    def _record_failure(self, err: BaseException, seq: int) -> None:
        """Keep the earliest failure (caller holds the lock): the consumer
        delivers everything sequenced before it, then raises it."""
        if self._err is None or seq < self._err_seq:
            self._err, self._err_seq = err, seq
        self._lock.notify_all()

    def _worker(self):
        me = threading.current_thread().name
        try:
            self._worker_loop(me)
        except WorkerKilled:
            # deliberate (injected) death: the thread exits; accounting
            # happens in the last-rites handler below
            pass
        finally:
            self._last_rites(me)

    def _worker_loop(self, me: str):
        while True:
            pulled = self._pull(me)
            if pulled is None:
                return
            seq, item = pulled
            timed = self._transform is not None and seq >= self._untimed
            if timed:
                with self._lock:
                    self._active[me] = time.perf_counter()
            try:
                if self._transform is not None:
                    item = self._transform(item)
            except WorkerKilled:
                # the reserved seq stays in _working: last rites record it
                raise
            except BaseException as e:  # surface in consumer
                with self._lock:
                    # a failed transform still spent IO time: bank it, so
                    # the error-path produce_s snapshot doesn't lose the
                    # final in-flight transform
                    t0 = self._active.pop(me, None)
                    if timed and t0 is not None:
                        self._produce_s += time.perf_counter() - t0
                    self._working.pop(me, None)
                    self._record_failure(e, seq)
                return
            dt = 0.0
            with self._lock:
                if timed:
                    t0 = self._active.pop(me, None)
                    if t0 is not None:
                        dt = time.perf_counter() - t0
                        self._produce_s += dt
                self._working.pop(me, None)
                if self._closed:
                    return
                self._buf[seq] = item
                self._lock.notify_all()
            idx = self._worker_idx.get(me)
            if idx is not None:
                self.monitor.beat(idx, seq, dt)

    def _last_rites(self, me: str) -> None:
        """Runs as the worker thread unwinds, however it died.  If the
        worker still holds a reserved-but-undelivered sequence number and
        the prefetcher is live, the consumer would otherwise park forever
        on the gap — record a ``WorkerDiedError`` at that seq (earliest
        failure wins, as usual) and mark the worker's heartbeat host dead
        so ``health()`` reports evict."""
        with self._lock:
            seq = self._working.pop(me, None)
            if seq is None or self._closed:
                return
            idx = self._worker_idx.get(me)
            if idx is not None:
                self.monitor.mark_dead(idx)
            self._record_failure(
                WorkerDiedError(
                    f"prefetch worker {me} died while producing item {seq}"
                ),
                seq,
            )

    def health(self, now: float | None = None) -> PolicyDecision:
        """Heartbeat-driven worker health: the ``StragglerPolicy`` decision
        over this prefetcher's workers (``proceed`` / ``drop`` / ``evict``).
        A worker that died via last rites is already marked not-alive on the
        monitor (so it no longer counts as silent) — report it as evict
        directly; otherwise defer to silence/straggle detection."""
        fallen = tuple(h.host_id for h in self.monitor.hosts.values()
                       if not h.alive)
        if fallen:
            return PolicyDecision("evict", fallen)
        return self._straggler.evaluate(now)

    # -- consumer side ------------------------------------------------------

    @property
    def closed(self) -> bool:
        """True once closed or exhausted; iteration yields nothing more."""
        with self._lock:
            return self._closed

    @property
    def produce_s(self) -> float:
        """Locked snapshot of transform seconds, in-progress work included."""
        with self._lock:
            now = time.perf_counter()
            return self._produce_s + sum(now - t0
                                         for t0 in self._active.values())

    def produce_time(self) -> float:
        """Callable form of ``produce_s`` for ``EngineReport`` plumbing."""
        return self.produce_s

    def _join_workers(self, timeout: float | None) -> list[str]:
        """Join every worker; returns the names of threads still alive."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        stuck = []
        for t in self._threads:
            t.join(None if deadline is None
                   else max(deadline - time.perf_counter(), 0.0))
            if t.is_alive():
                stuck.append(t.name)
        return stuck

    def close(self, timeout: float = 5.0) -> None:
        """Cancel the prefetch: wake parked workers and consumer, drop
        buffered items, and join the threads.  Idempotent; safe after
        normal exhaustion.  A worker that fails to join within ``timeout``
        (a source wedged in foreign code) is reported by name with a
        ``RuntimeWarning`` — a silent leak here would defeat the
        thread-leak fixture's intent."""
        with self._lock:
            self._closed = True
            self._buf.clear()
            self._lock.notify_all()
        stuck = self._join_workers(timeout)
        if stuck:
            warnings.warn(
                f"BoundedPrefetcher.close(): worker thread(s) "
                f"{', '.join(stuck)} did not join within {timeout}s; "
                f"the source may be blocked outside our control",
                RuntimeWarning, stacklevel=2,
            )

    def __enter__(self) -> "BoundedPrefetcher":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        failed = False
        with self._lock:
            while True:
                if self._closed:
                    raise StopIteration
                if self._next_out in self._buf:
                    item = self._buf.pop(self._next_out)
                    self._next_out += 1
                    self._lock.notify_all()  # frees a depth token
                    return item
                # nothing deliverable yet: either the stream is over, the
                # earliest failure is next in line, or we park until a
                # worker/close() notifies — no timeout, no polling
                failed = (self._err is not None
                          and self._next_out >= self._err_seq)
                if failed or (self._exhausted_at is not None
                              and self._next_out >= self._exhausted_at):
                    break
                self._lock.wait()
        # end of stream (or failure boundary): workers are already
        # returning — join outside the lock, then settle the final state
        self._join_workers(None)
        with self._lock:
            self._closed = True  # exhausted: later close() is a no-op
            self._buf.clear()
            err = self._err
            self._lock.notify_all()
        if failed and err is not None:
            raise err
        raise StopIteration

    # -- compatibility ------------------------------------------------------

    @property
    def _thread(self) -> threading.Thread:
        """The first worker thread (the only one when ``workers=1``) —
        kept for callers/tests that predate multi-worker prefetch."""
        return self._threads[0]
