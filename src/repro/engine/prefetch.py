"""Bounded-queue background prefetch: the one producer/consumer primitive.

This is the double-buffer discipline every previous copy of the pipeline
hand-rolled (``core.stream``'s producer thread, ``data.pipeline``'s
``Prefetcher``): a worker thread pulls items from an iterable, optionally
transforms them (device_put, shard placement, decompression — the "IO"
stage), and feeds a depth-bounded queue.  The bounded queue is the
backpressure, exactly like the DPU's receive queues: when the device falls
behind, the producer blocks instead of buffering unboundedly.

Exceptions raised by the source or the transform are re-raised in the
consumer thread, after all successfully produced items are drained.

Lifecycle: a consumer that stops early (breaks out of its loop, or a
pipeline that dies mid-stream) calls ``close()`` — the worker is signalled
to stop, queued items are dropped, and the thread is joined, so no producer
thread outlives its pipeline.  ``BoundedPrefetcher`` is also a context
manager (``__exit__`` closes); closing an exhausted or already-closed
prefetcher is a no-op.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Iterator

_STOP = object()

# How often a blocked worker re-checks the close signal.  Wakeups on a full
# queue are condition-driven (put returns as soon as space frees); the
# timeout only bounds how long a cancelled worker lingers.
_POLL_S = 0.05


class BoundedPrefetcher:
    """Background-thread prefetch of an iterable, depth-bounded.

    Attributes:
      produce_s: cumulative seconds the worker spent in ``transform`` —
        the pipeline's IO-side cost, reported in ``EngineReport.produce_s``.
    """

    def __init__(self, it: Iterable, depth: int = 2,
                 transform: Callable | None = None,
                 untimed_items: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._closed = threading.Event()
        # orders worker-side writes of produce_s/_err against consumer
        # reads: += is a read-modify-write the GIL does not make atomic
        self._lock = threading.Lock()
        self.produce_s = 0.0

        def put_until_closed(item) -> bool:
            while not self._closed.is_set():
                try:
                    self._q.put(item, timeout=_POLL_S)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for i, item in enumerate(it):
                    if self._closed.is_set():
                        return
                    if transform is not None:
                        t0 = time.perf_counter()
                        item = transform(item)
                        if i >= untimed_items:
                            # warmup items are excluded from produce_s the
                            # same way the consumer excludes them from
                            # elapsed/process accounting
                            dt = time.perf_counter() - t0
                            with self._lock:
                                self.produce_s += dt
                    if not put_until_closed(item):
                        return
            except BaseException as e:  # surface in consumer
                with self._lock:
                    self._err = e
            finally:
                put_until_closed(_STOP)

        # the name is load-bearing: the thread-leak fixture in
        # tests/conftest.py fails any test that leaves a repro-* thread
        # alive, which is what pins the close() discipline
        self._thread = threading.Thread(
            target=worker, daemon=True, name="repro-prefetch-worker"
        )
        self._thread.start()

    @property
    def closed(self) -> bool:
        """True once closed or exhausted; iteration yields nothing more."""
        return self._closed.is_set()

    def close(self) -> None:
        """Cancel the prefetch: signal the worker, drop queued items, and
        join the thread.  Idempotent; safe after normal exhaustion."""
        already = self._closed.is_set()
        self._closed.set()
        if not already:
            # unblock a worker stuck on a full queue
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "BoundedPrefetcher":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        # timed get + closed recheck: close() may be called from another
        # thread (a watchdog) while the consumer is parked on an empty
        # queue, in which case no _STOP sentinel will ever arrive
        while True:
            if self._closed.is_set():
                raise StopIteration
            try:
                item = self._q.get(timeout=_POLL_S)
                break
            except queue.Empty:
                continue
        if item is _STOP:
            self._thread.join()
            self._closed.set()  # exhausted: later close() is a no-op
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
