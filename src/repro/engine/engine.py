"""TrafficEngine: one API over every ingest topology.

    engine = TrafficEngine(WindowConfig(...), policy="double_buffered",
                           sinks=[StatsAccumulator()])
    report = engine.run("uniform", n_batches=8, warmup_items=1)
    totals = engine.finalize()["stats"]

Composition is Source -> StageGraph -> Sinks under an ExecutionPolicy (see
DESIGN.md).  The engine derives the stage graph's outputs from what the
attached sinks require, checks policy/sink compatibility, and stamps the
unified telemetry (pkt/s, produce/process split, merge overflow) into the
returned ``EngineReport``.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.window import WindowConfig
from repro.engine.policies import ExecutionPolicy, ShardedPolicy, make_policy
from repro.engine.sinks import Sink
from repro.engine.source import Source, as_source
from repro.engine.stages import (
    DEFAULT_OUTPUTS,
    WORKLOAD_INPUT_KEY,
    WORKLOAD_STAGES,
    StageGraph,
    extend_stages_for,
)
from repro.engine.telemetry import EngineReport


class TrafficEngine:
    """The paper's pipeline, assembled from pluggable parts.

    ``workload`` selects the input record type and default stage graph:
    ``"packets"`` (the paper's (src, dst) pairs, anonymize -> build -> merge
    -> analytics) or ``"flow"`` (Suricata-style flow records with
    byte/packet value payloads, anonymize_flows -> build_flow -> merge_flow
    -> analytics).  Either way the engine derives the graph's outputs from
    what the attached sinks require, auto-appending registered stages able
    to provide them (e.g. an AnomalySink pulls in the ``fanout`` stage).
    """

    def __init__(
        self,
        cfg: WindowConfig,
        *,
        workload: str = "packets",
        stages: Sequence[str] | None = None,
        outputs: Sequence[str] | None = None,
        sinks: Sequence[Sink] = (),
        policy: str | ExecutionPolicy = "blocking",
    ):
        self.cfg = cfg
        self.sinks = list(sinks)
        self.policy = make_policy(policy)
        if workload not in WORKLOAD_STAGES:
            raise ValueError(
                f"unknown workload {workload!r}; choose from "
                f"{sorted(WORKLOAD_STAGES)}"
            )
        self.workload = workload
        input_key = WORKLOAD_INPUT_KEY[workload]

        required = list(outputs if outputs is not None else DEFAULT_OUTPUTS)
        for sink in self.sinks:
            for key in sink.requires:
                if key not in required:
                    required.append(key)

        if isinstance(self.policy, ShardedPolicy):
            # The sharded step (pipelined or not) fuses the graph per shard
            # and only emits the exact global stats — matrix-hungry sinks
            # can't be fed.
            unsupported = sorted(set(required) - {"stats", "merge_overflow"})
            if unsupported:
                raise ValueError(
                    f"sharded policy {self.policy.name!r} cannot produce "
                    f"outputs {unsupported} "
                    f"(sinks: {[s.name for s in self.sinks]})"
                )
            self.graph = None
        else:
            selected = (stages if stages is not None
                        else WORKLOAD_STAGES[workload])
            selected = extend_stages_for(selected, required, input_key)
            self.graph = StageGraph(cfg, stages=selected, outputs=required,
                                    input_key=input_key)
        self._process_fn = None
        self._overflow = 0

    def make_source(self, spec="uniform", *, n_batches: int = 8,
                    seed: int = 0) -> Source:
        """Build a Source with this engine's window geometry + workload."""
        return as_source(
            spec,
            window_size=self.cfg.window_size,
            windows_per_batch=self.cfg.windows_per_batch,
            n_batches=n_batches, seed=seed, workload=self.workload,
        )

    def run(self, source="uniform", *, n_batches: int = 8, seed: int = 0,
            warmup_items: int = 0, keep_results: bool = True
            ) -> EngineReport:
        """Drive ``source`` through the pipeline; returns the telemetry.

        ``source`` may be a Source, an iterable of batches, ``"uniform"`` /
        ``"zipf"``, or a pcap-lite path (``n_batches``/``seed`` apply to the
        synthetic kinds).  The first ``warmup_items`` batches run but are
        excluded from timing, packet counts, and sink delivery (jit
        compile).  ``keep_results=False`` drops per-batch outputs once the
        sinks have consumed them, keeping long runs O(1) in memory.
        """
        src = self.make_source(source, n_batches=n_batches, seed=seed)
        if self._process_fn is None:
            self._process_fn = self.policy.build_process_fn(
                self.graph, self.cfg, workload=self.workload
            )
        self._overflow = 0
        report = self.policy.run(
            src, self._process_fn,
            packets_per_item=src.packets_per_item,
            warmup_items=warmup_items,
            consume=self._dispatch,
            keep_results=keep_results,
        )
        report.merge_overflow = self._overflow
        return report

    def finalize(self) -> dict:
        """Collect every sink's result, keyed by sink name."""
        return {s.name: s.finalize() for s in self.sinks}

    def _dispatch(self, index: int, outputs) -> None:
        if isinstance(outputs, dict) and "merge_overflow" in outputs:
            self._overflow += int(np.asarray(outputs["merge_overflow"]))
        for sink in self.sinks:
            sink.consume(index, outputs)
