"""TrafficEngine: one API over every ingest topology.

    engine = TrafficEngine(WindowConfig(...), policy="double_buffered",
                           sinks=[StatsAccumulator()])
    report = engine.run("uniform", n_batches=8, warmup_items=1)
    totals = engine.finalize()["stats"]

Composition is Source -> StageGraph -> Sinks under an ExecutionPolicy (see
DESIGN.md).  The engine derives the stage graph's outputs from what the
attached sinks require, checks policy/sink compatibility, and stamps the
unified telemetry (pkt/s, produce/process split, merge overflow) into the
returned ``EngineReport``.
"""

from __future__ import annotations

import warnings
from typing import Sequence

import numpy as np

from repro.core.window import WindowConfig
from repro.engine.faults import FaultTolerance, RetryingSource
from repro.engine.policies import ExecutionPolicy, ShardedPolicy, make_policy
from repro.engine.sinks import Sink
from repro.engine.source import Source, as_source, fast_forward
from repro.engine.stages import (
    DEFAULT_OUTPUTS,
    WORKLOAD_INPUT_KEY,
    WORKLOAD_STAGES,
    StageGraph,
    extend_stages_for,
)
from repro.engine.telemetry import EngineReport


class TrafficEngine:
    """The paper's pipeline, assembled from pluggable parts.

    ``workload`` selects the input record type and default stage graph:
    ``"packets"`` (the paper's (src, dst) pairs, anonymize -> build -> merge
    -> analytics) or ``"flow"`` (Suricata-style flow records with
    byte/packet value payloads, anonymize_flows -> build_flow -> merge_flow
    -> analytics).  Either way the engine derives the graph's outputs from
    what the attached sinks require, auto-appending registered stages able
    to provide them (e.g. an AnomalySink pulls in the ``fanout`` stage).
    """

    def __init__(
        self,
        cfg: WindowConfig,
        *,
        workload: str = "packets",
        stages: Sequence[str] | None = None,
        outputs: Sequence[str] | None = None,
        sinks: Sequence[Sink] = (),
        policy: str | ExecutionPolicy = "blocking",
    ):
        self.cfg = cfg
        self.sinks = list(sinks)
        self.policy = make_policy(policy)
        if workload not in WORKLOAD_STAGES:
            raise ValueError(
                f"unknown workload {workload!r}; choose from "
                f"{sorted(WORKLOAD_STAGES)}"
            )
        self.workload = workload
        input_key = WORKLOAD_INPUT_KEY[workload]

        required = list(outputs if outputs is not None else DEFAULT_OUTPUTS)
        for sink in self.sinks:
            for key in sink.requires:
                if key not in required:
                    required.append(key)

        if isinstance(self.policy, ShardedPolicy):
            # The sharded step (pipelined or not) fuses the graph per shard
            # and only emits the exact global stats — matrix-hungry sinks
            # can't be fed.
            unsupported = sorted(set(required) - {"stats", "merge_overflow"})
            if unsupported:
                raise ValueError(
                    f"sharded policy {self.policy.name!r} cannot produce "
                    f"outputs {unsupported} "
                    f"(sinks: {[s.name for s in self.sinks]})"
                )
            self.graph = None
        else:
            selected = (stages if stages is not None
                        else WORKLOAD_STAGES[workload])
            selected = extend_stages_for(selected, required, input_key)
            self.graph = StageGraph(cfg, stages=selected, outputs=required,
                                    input_key=input_key)
        self._process_fn = None
        self._overflow = 0
        # per-run fault-tolerance / checkpoint state (set by run())
        self._active_sinks: list[Sink] = self.sinks
        self._sink_failure_mode = "raise"
        self._ft: FaultTolerance | None = None
        self._retrier: RetryingSource | None = None
        self._ckpt_mgr = None
        self._ckpt_every = 0
        self._ckpt_measured_base = 0
        self._ckpt_stream_base = 0
        self._ckpt_warmup = 0
        self._ckpt_per_item = 0
        self._ckpt_prior_counters: dict = {}
        self._ckpt_meta: dict = {}
        self._ckpt_written = 0
        self._ckpt_last_step = -1
        self._last_index = -1

    def make_source(self, spec="uniform", *, n_batches: int = 8,
                    seed: int = 0) -> Source:
        """Build a Source with this engine's window geometry + workload."""
        return as_source(
            spec,
            window_size=self.cfg.window_size,
            windows_per_batch=self.cfg.windows_per_batch,
            n_batches=n_batches, seed=seed, workload=self.workload,
        )

    def run(self, source="uniform", *, n_batches: int = 8, seed: int = 0,
            warmup_items: int = 0, keep_results: bool = True,
            fault_tolerance: FaultTolerance | None = None,
            checkpoint_every: int = 0, checkpoint_manager=None,
            resume: bool = False) -> EngineReport:
        """Drive ``source`` through the pipeline; returns the telemetry.

        ``source`` may be a Source, an iterable of batches, ``"uniform"`` /
        ``"zipf"``, or a pcap-lite path (``n_batches``/``seed`` apply to the
        synthetic kinds).  The first ``warmup_items`` batches run but are
        excluded from timing, packet counts, and sink delivery (jit
        compile).  ``keep_results=False`` drops per-batch outputs once the
        sinks have consumed them, keeping long runs O(1) in memory.

        ``fault_tolerance`` (a ``faults.FaultTolerance``) wraps the source
        in the injection/retry/quarantine layers and stamps the run's fault
        accounting into the report.  ``checkpoint_every=k`` writes a
        crash-consistent engine checkpoint (sink state, merge overflow,
        stream cursor) to ``checkpoint_manager`` after every k-th measured
        batch; ``resume=True`` restores the latest checkpoint (cold-starts
        if none exists), fast-forwards the source past everything the
        crashed run disposed of, and folds the checkpointed batch/packet/
        fault totals into the returned report — so a killed-and-resumed
        run finalizes bit-identically to an uninterrupted one.
        """
        ft = fault_tolerance
        if (checkpoint_every or resume) and checkpoint_manager is None:
            raise ValueError(
                "checkpoint_every/resume require a checkpoint_manager"
            )
        if ft is not None:
            ft.counters.reset()
            if ft.quarantine is not None and ft.quarantine not in self.sinks:
                self.sinks.append(ft.quarantine)
        prior = None
        start_measured = 0
        start_stream = 0
        self._overflow = 0
        if resume:
            state, _meta = checkpoint_manager.restore(None)
            if state is not None:
                self._load_checkpoint_state(state)
                prior = state
                start_measured = int(state["batches_done"])
                start_stream = int(state.get("stream_pos", start_measured))
        if start_stream and warmup_items:
            raise ValueError(
                "warmup_items must be 0 when resuming from a checkpoint: "
                "warmup would consume (and discard) resumed stream items"
            )
        src = self.make_source(source, n_batches=n_batches, seed=seed)
        per_item = src.packets_per_item
        if checkpoint_every and per_item is None:
            raise ValueError(
                "checkpointing requires a source with a known "
                "packets_per_item (exact packet accounting in checkpoints)"
            )
        if start_stream:
            src = fast_forward(src, start_stream)
        wrapped = src
        if ft is not None:
            wrapped = ft.wrap_source(src, cfg=self.cfg,
                                     workload=self.workload)
        self._active_sinks = (ft.wrap_sinks(self.sinks) if ft is not None
                              else self.sinks)
        self._sink_failure_mode = (ft.sink_failures if ft is not None
                                   else "raise")
        self._ft = ft
        self._retrier = (wrapped if isinstance(wrapped, RetryingSource)
                         else None)
        self._ckpt_mgr = checkpoint_manager if checkpoint_every else None
        self._ckpt_every = int(checkpoint_every)
        self._ckpt_measured_base = start_measured
        self._ckpt_stream_base = start_stream
        self._ckpt_warmup = int(warmup_items)
        self._ckpt_per_item = int(per_item or 0)
        self._ckpt_prior_counters = (dict(prior.get("counters") or {})
                                     if prior is not None else {})
        self._ckpt_meta = {
            "workload": self.workload,
            "policy": self.policy.name,
            "window_size": int(self.cfg.window_size),
            "windows_per_batch": int(self.cfg.windows_per_batch),
            "seed": int(seed),
            "source": (source if isinstance(source, str)
                       else type(source).__name__),
        }
        self._ckpt_written = 0
        self._ckpt_last_step = -1
        self._last_index = -1
        if self._process_fn is None:
            self._process_fn = self.policy.build_process_fn(
                self.graph, self.cfg, workload=self.workload
            )
        try:
            report = self.policy.run(
                wrapped, self._process_fn,
                packets_per_item=per_item,
                warmup_items=warmup_items,
                consume=self._dispatch,
                keep_results=keep_results,
            )
        except BaseException:
            # Failure path (source error, WorkerKilled, sink-write
            # failure): release every sink's OS resources so a crashed
            # run leaks no fds.  Success paths leave sinks open —
            # finalize() still needs them (and closes its own).
            self._close_sinks()
            raise
        finally:
            closer = getattr(wrapped, "close", None)
            if closer is not None:
                closer()
        report.merge_overflow = self._overflow
        report.checkpoints_written = self._ckpt_written
        report.resumed_from = start_measured
        if ft is not None:
            snap = ft.counters.snapshot()
            report.retries = snap["retries"]
            report.faults_injected = snap["faults_injected"]
            report.batches_quarantined = snap["batches_quarantined"]
            report.packets_dropped = snap["packets_dropped"]
            report.sink_write_failures = snap["sink_write_failures"]
        if prior is not None:
            pc = self._ckpt_prior_counters
            report.batches += start_measured
            report.packets += int(prior.get("packets_done", 0))
            report.retries += int(pc.get("retries", 0))
            report.faults_injected += int(pc.get("faults_injected", 0))
            report.batches_quarantined += int(
                pc.get("batches_quarantined", 0))
            report.packets_dropped += int(pc.get("packets_dropped", 0))
            report.sink_write_failures += int(
                pc.get("sink_write_failures", 0))
        return report

    def finalize(self) -> dict:
        """Collect every sink's result, keyed by sink name."""
        return {s.name: s.finalize() for s in self.sinks}

    @property
    def batches_consumed(self) -> int:
        """Measured batches dispatched so far, resume chain included."""
        return self._ckpt_measured_base + self._last_index + 1

    def _close_sinks(self) -> None:
        for sink in self._active_sinks:
            try:
                sink.close()
            except Exception as e:  # noqa: BLE001
                warnings.warn(
                    f"sink {sink.name!r} failed to close: {e!r}",
                    RuntimeWarning, stacklevel=2,
                )

    def close(self) -> None:
        """Release every sink's OS resources without finalizing."""
        self._close_sinks()

    def _dispatch(self, index: int, outputs) -> None:
        self._last_index = index
        if isinstance(outputs, dict) and "merge_overflow" in outputs:
            self._overflow += int(np.asarray(outputs["merge_overflow"]))
        for sink in self._active_sinks:
            try:
                sink.consume(index, outputs)
            except Exception as e:
                if self._sink_failure_mode != "record":
                    raise
                self._ft.counters.add("sink_write_failures")
                warnings.warn(
                    f"sink {sink.name!r} failed on batch {index}: {e!r}; "
                    "continuing (sink_failures='record')",
                    RuntimeWarning, stacklevel=2,
                )
        if self._ckpt_every:
            measured_done = self._ckpt_measured_base + index + 1
            if measured_done % self._ckpt_every == 0:
                self._save_checkpoint(index, measured_done)

    # -- checkpoint plumbing -------------------------------------------------

    def _save_checkpoint(self, index: int, measured_done: int) -> None:
        """Write the engine's crash-consistent window state.

        ``stream_pos`` is the cursor a resumed run fast-forwards the source
        by: the number of stream items the run has *disposed of* (delivered
        + warmup + skipped + quarantined) up to this batch — taken from the
        retry layer when one is present, since only it knows about skips.
        """
        stream_rel = self._stream_rel(index)
        state = {
            "batches_done": int(measured_done),
            "stream_pos": int(self._ckpt_stream_base + stream_rel),
            "packets_done": int(measured_done * self._ckpt_per_item),
            "merge_overflow": int(self._overflow),
            "counters": self._cumulative_counters(),
            "sinks": {s.name: s.state_dict() for s in self.sinks},
        }
        self._ckpt_mgr.save(measured_done, state, meta=self._ckpt_meta,
                            portable=True)
        self._ckpt_written += 1
        self._ckpt_last_step = measured_done

    def _stream_rel(self, index: int) -> int:
        """Stream items this run has disposed of by batch ``index``."""
        if index < 0:
            return self._ckpt_warmup
        if self._retrier is not None:
            return self._retrier.delivered_pos(self._ckpt_warmup + index)
        return self._ckpt_warmup + index + 1

    def checkpoint_now(self) -> int | None:
        """Write a checkpoint at the current drain position.

        The daemon's clean-shutdown hook: after ``run`` returns (or at a
        quiesce point), persist exactly what has been consumed so the
        next start can ``resume=True`` from it.  Returns the checkpoint
        step, or None when checkpointing is not configured or the
        current position was already checkpointed by the periodic path.
        """
        if self._ckpt_mgr is None:
            return None
        measured_done = self._ckpt_measured_base + self._last_index + 1
        if measured_done == self._ckpt_last_step:
            return None
        self._save_checkpoint(self._last_index, measured_done)
        return measured_done

    def _cumulative_counters(self) -> dict:
        """Fault counters across the whole resume chain (prior + this run).
        Best-effort: prefetch workers pull ahead of consumption, so a
        checkpoint may include retry work for batches not yet consumed."""
        out = {k: int(v) for k, v in self._ckpt_prior_counters.items()}
        if self._ft is not None:
            for k, v in self._ft.counters.snapshot().items():
                out[k] = out.get(k, 0) + int(v)
        return out

    def _load_checkpoint_state(self, state: dict) -> None:
        sink_states = state.get("sinks") or {}
        by_name: dict[str, Sink] = {}
        for s in self.sinks:
            if s.name in by_name:
                raise ValueError(
                    f"cannot resume: duplicate sink name {s.name!r}"
                )
            by_name[s.name] = s
        for name, s_state in sink_states.items():
            sink = by_name.get(name)
            if sink is None:
                raise ValueError(
                    f"cannot resume: checkpoint carries state for sink "
                    f"{name!r}, which is not attached to this engine "
                    f"(attached: {sorted(by_name)})"
                )
            sink.load_state_dict(s_state)
        missing = sorted(set(by_name) - set(sink_states))
        if missing:
            raise ValueError(
                f"cannot resume: sinks {missing} have no state in the "
                "checkpoint (they were not attached when it was written)"
            )
        self._overflow = int(state.get("merge_overflow", 0))
