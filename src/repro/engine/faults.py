"""Deterministic fault injection + retry/quarantine for the engine.

Every failure mode the long-running collector must survive is modeled as a
seed-keyed, reproducible fault:

* ``transient`` — a source read that fails N times, then succeeds (flaky
  capture device; the retry path's bread and butter);
* ``permanent`` — a source read that never succeeds (dead capture ring);
* ``slow``      — a read delayed by ``delay_s`` (backpressure / saturated
  NIC; trips the per-attempt timeout when one is configured);
* ``poison``    — the read succeeds but the batch is corrupted (truncated
  trailing axis) and fails stage validation — routed to the quarantine
  dead-letter path instead of killing the run;
* ``sink``      — a sink write fails at a given batch index;
* ``kill-worker`` — the thread performing the read dies (``WorkerKilled``,
  a BaseException the prefetcher turns into worker last rites);
* ``crash``     — plain ``RuntimeError``: simulated process death, used by
  the resume chaos tests (not retryable, not recorded as survivable).

A ``FaultPlan`` is an explicit list of ``FaultSpec``s (or ``parse``/
``random(seed)`` built), so tests and benchmarks replay the exact same
failure schedule every run.  ``FaultInjectingSource`` raises read faults
*before* consuming the wrapped source's item — a retried batch is the same
batch, and the stream content is unchanged by transient faults.  Batch
indices in a plan are *stream* indices as seen by the injector (warmup
batches included, when the engine adds one).

``RetryingSource`` is the survival layer: bounded retries with exponential
backoff for transient errors, an optional per-attempt timeout (a hung read
is charged as a failed attempt), and — when retries exhaust or a batch
fails validation — either a clean raise or a skip/quarantine with honest
accounting (``FaultCounters``: retries, faults_injected,
batches_quarantined, packets_dropped, sink_write_failures) that the engine
copies into ``EngineReport``.
"""

from __future__ import annotations

import collections
import dataclasses
import random
import threading
import time
import warnings
from pathlib import Path
from typing import Callable, Iterable

import numpy as np

from repro.engine.prefetch import WorkerDiedError, WorkerKilled
from repro.engine.sinks import Sink
from repro.engine.source import Source

__all__ = [
    "FAULT_KINDS",
    "FaultCounters",
    "FaultInjectingSink",
    "FaultInjectingSource",
    "FaultPlan",
    "FaultSpec",
    "FaultTolerance",
    "PermanentSourceError",
    "PoisonedBatchError",
    "QuarantineSink",
    "RetryingSource",
    "SinkWriteError",
    "SourceTimeoutError",
    "TransientSourceError",
    "WorkerDiedError",
    "WorkerKilled",
    "make_batch_validator",
]


class TransientSourceError(RuntimeError):
    """A source read that may succeed if retried."""


class PermanentSourceError(RuntimeError):
    """A source read that will never succeed; retrying is pointless."""


class SourceTimeoutError(RuntimeError):
    """A source read exceeded the per-attempt timeout too many times."""


class SinkWriteError(RuntimeError):
    """A sink failed to persist a batch's outputs."""


class PoisonedBatchError(RuntimeError):
    """A batch failed validation and there is no quarantine to take it."""


FAULT_KINDS = ("transient", "permanent", "slow", "poison", "sink",
               "kill-worker", "crash")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: ``kind`` at stream-batch ``batch``.

    ``count`` is how many times a transient fault fires before the read
    succeeds; ``delay_s`` is the injected latency of a slow read.
    """

    kind: str
    batch: int
    count: int = 1
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}"
            )
        if self.batch < 0:
            raise ValueError(f"fault batch must be >= 0, got {self.batch}")
        if self.count < 1:
            raise ValueError(f"fault count must be >= 1, got {self.count}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible fault schedule: a tuple of ``FaultSpec``s.

    Build explicitly, via ``parse`` (the CLI grammar), or via
    ``random(seed)`` — the same seed always yields the same plan.
    """

    specs: tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def __bool__(self) -> bool:
        return bool(self.specs)

    def source_specs(self) -> tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.kind != "sink")

    def sink_batches(self) -> set[int]:
        return {s.batch for s in self.specs if s.kind == "sink"}

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the CLI grammar: comma-separated ``kind[:arg]@batch``.

        ``arg`` is the retry count for ``transient`` and the delay seconds
        for ``slow``; other kinds take no argument.  Example:
        ``"transient:2@1,slow:0.05@2,poison@3,sink@2,crash@5"``.
        """
        specs = []
        for part in filter(None, (p.strip() for p in text.split(","))):
            head, sep, batch = part.partition("@")
            if not sep:
                raise ValueError(
                    f"bad fault spec {part!r}: expected kind[:arg]@batch"
                )
            kind, _, arg = head.partition(":")
            kw: dict = {}
            if arg:
                if kind == "transient":
                    kw["count"] = int(arg)
                elif kind == "slow":
                    kw["delay_s"] = float(arg)
                else:
                    raise ValueError(
                        f"fault kind {kind!r} takes no argument, got {arg!r}"
                    )
            specs.append(FaultSpec(kind=kind, batch=int(batch), **kw))
        return cls(specs=tuple(specs))

    @classmethod
    def random(cls, seed: int, n_batches: int,
               rates: dict[str, float] | None = None) -> "FaultPlan":
        """Seed-keyed random plan over ``n_batches`` stream batches.

        ``rates`` maps fault kind -> per-batch probability; the default
        exercises only the survivable kinds (transient/slow/poison).
        """
        rates = dict(rates if rates is not None
                     else {"transient": 0.2, "slow": 0.1, "poison": 0.1})
        for kind in rates:
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in rates")
        rng = random.Random(seed)
        specs = []
        for b in range(n_batches):
            for kind in sorted(rates):
                if rng.random() >= rates[kind]:
                    continue
                if kind == "transient":
                    specs.append(FaultSpec(kind, b,
                                           count=rng.randint(1, 2)))
                elif kind == "slow":
                    specs.append(FaultSpec(
                        kind, b, delay_s=round(rng.uniform(0.005, 0.02), 4)
                    ))
                else:
                    specs.append(FaultSpec(kind, b))
        return cls(specs=tuple(specs))


class FaultCounters:
    """Thread-safe honest accounting of what a degraded run survived.

    One instance per run (``FaultTolerance`` owns and resets it); the
    engine copies the final snapshot into ``EngineReport``.
    """

    FIELDS = ("retries", "faults_injected", "batches_quarantined",
              "packets_dropped", "sink_write_failures")

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            for f in self.FIELDS:
                setattr(self, f, 0)

    def add(self, name: str, n: int = 1) -> None:
        if name not in self.FIELDS:
            raise ValueError(f"unknown fault counter {name!r}")
        with self._lock:
            setattr(self, name, getattr(self, name) + int(n))

    def snapshot(self) -> dict[str, int]:
        with self._lock:
            return {f: int(getattr(self, f)) for f in self.FIELDS}


def _poison(item):
    """Deterministically corrupt a batch: truncate the trailing axis so the
    payload width no longer matches the workload (fails validation)."""
    return item[..., :-1]


@dataclasses.dataclass
class _Pending:
    spec: FaultSpec
    remaining: int = 0
    fired: bool = False

    def __post_init__(self):
        self.remaining = self.spec.count


class FaultInjectingSource(Source):
    """Wrap a source; raise/modify reads according to a ``FaultPlan``.

    Read faults fire *before* the wrapped item is consumed, so a retry
    re-attempts the same batch and the stream content is unchanged once
    the fault clears.  The batch index advances only on delivery (or an
    explicit ``skip_current`` from the retry layer).
    """

    def __init__(self, inner, plan: FaultPlan,
                 counters: FaultCounters | None = None):
        self.inner = inner
        self.plan = plan
        self.counters = counters if counters is not None else FaultCounters()
        self.packets_per_item = getattr(inner, "packets_per_item", None)

    def __iter__(self) -> "_FaultIter":
        return _FaultIter(self)


class _FaultIter:
    def __init__(self, src: FaultInjectingSource):
        self._inner = iter(src.inner)
        self._counters = src.counters
        self._i = 0
        self._done = False
        self._pending: dict[int, list[_Pending]] = {}
        for spec in src.plan.source_specs():
            self._pending.setdefault(spec.batch, []).append(_Pending(spec))

    def __iter__(self) -> "_FaultIter":
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        i = self._i
        faults = self._pending.get(i, [])
        for f in faults:
            kind = f.spec.kind
            if kind == "transient":
                if f.remaining > 0:
                    f.remaining -= 1
                    self._counters.add("faults_injected")
                    raise TransientSourceError(
                        f"injected transient read error at stream batch {i}"
                        f" ({f.remaining} more before success)"
                    )
            elif kind == "permanent":
                if not f.fired:
                    f.fired = True
                    self._counters.add("faults_injected")
                raise PermanentSourceError(
                    f"injected permanent read error at stream batch {i}"
                )
            elif kind == "crash":
                if not f.fired:
                    f.fired = True
                    self._counters.add("faults_injected")
                raise RuntimeError(
                    f"injected crash at stream batch {i}"
                )
            elif kind == "kill-worker":
                if not f.fired:
                    f.fired = True
                    self._counters.add("faults_injected")
                raise WorkerKilled(
                    f"injected worker death at stream batch {i}"
                )
        try:
            item = next(self._inner)
        except StopIteration:
            self._done = True
            raise
        for f in faults:
            kind = f.spec.kind
            if f.fired:
                continue
            if kind == "slow":
                f.fired = True
                self._counters.add("faults_injected")
                time.sleep(f.spec.delay_s)
            elif kind == "poison":
                f.fired = True
                self._counters.add("faults_injected")
                item = _poison(item)
        self._i = i + 1
        return item

    def skip_current(self) -> bool:
        """Abandon the current batch: drop its remaining faults, consume
        and discard the wrapped item, advance.  Returns True if a stream
        item was actually consumed (False: the source had already ended).
        """
        if self._done:
            return False
        self._pending.pop(self._i, None)
        try:
            next(self._inner)
        except StopIteration:
            self._done = True
            return False
        self._i += 1
        return True


def make_batch_validator(cfg, workload: str = "packets") -> Callable:
    """Validator for raw source batches against the engine geometry.

    Returns a callable ``validate(item) -> None | str`` (None = valid,
    str = human-readable reason).  This is the stage-validation gate a
    poisoned batch fails: rank-3 ``[windows_per_batch, window_size, width]``
    uint32, width 2 for packets and ``FLOW_WIDTH`` for flows.
    """
    from repro.data.flows import FLOW_WIDTH

    width = FLOW_WIDTH if workload == "flow" else 2
    expect = (cfg.windows_per_batch, cfg.window_size, width)

    def validate(item):
        shape = tuple(getattr(item, "shape", ()) or ())
        if len(shape) != 3 or shape != expect:
            return f"expected shape {expect}, got {shape}"
        dtype = getattr(item, "dtype", None)
        if dtype is None or np.dtype(dtype) != np.uint32:
            return f"expected uint32 payload, got dtype {dtype}"
        return None

    return validate


class QuarantineSink(Sink):
    """Dead-letter path: poisoned batches land here instead of killing
    the run.  Entries record the stream index, the validation reason, and
    (by default) the offending payload, so an operator can replay or
    inspect exactly what was dropped."""

    name = "quarantine"
    requires: tuple[str, ...] = ()

    #: frame kind tag for dead-letter log entries
    FRAME_KIND = 0x51  # 'Q'

    def __init__(self, keep_payload: bool = True,
                 path: str | Path | None = None):
        self.keep_payload = keep_payload
        self.path = Path(path) if path is not None else None
        self.entries: list[dict] = []
        self._log = None

    def _ensure_log(self):
        if self._log is None and self.path is not None:
            from repro.checkpoint.framelog import FrameLog

            # FrameLog appends; an existing dead-letter file from a prior
            # run is never clobbered — resume truncates to the checkpoint
            # cursor instead (load_state_dict).
            self._log = FrameLog(self.path)
        return self._log

    def quarantine(self, index: int, item, reason: str) -> None:
        rec: dict = {"index": int(index), "reason": str(reason)}
        if self.keep_payload and hasattr(item, "shape"):
            import jax

            rec["batch"] = np.asarray(jax.device_get(item))
        self.entries.append(rec)
        log = self._ensure_log()
        if log is not None:
            log.append(self.FRAME_KIND, rec)

    def consume(self, index: int, outputs: dict) -> None:
        # not fed by the stage graph; entries arrive via quarantine()
        return None

    def finalize(self) -> dict:
        self.close()
        out = {"batches": len(self.entries), "entries": list(self.entries)}
        if self.path is not None:
            out["path"] = str(self.path)
        return out

    def close(self) -> None:
        if self._log is not None:
            self._log.close()

    def state_dict(self) -> dict:
        state = {"entries": list(self.entries)}
        if self.path is not None:
            # Byte cursor into the dead-letter log at checkpoint time:
            # everything at or before it is durably accounted for by this
            # checkpoint; everything after it belongs to batches the
            # resumed run will replay (and re-quarantine identically).
            log = self._ensure_log()
            state["log_pos"] = int(log.tell())
        return state

    def load_state_dict(self, state: dict) -> None:
        self.entries = list(state["entries"])
        if self.path is not None and "log_pos" in state:
            from repro.checkpoint.framelog import FrameLog

            self._log = FrameLog(self.path)
            self._log.truncate_to(int(state["log_pos"]))


class _AttemptTimeout(Exception):
    """Internal: one timed pull attempt expired (the pull stays pending)."""


class _TimeoutPuller:
    """Single persistent pull thread so a hung source read can be timed
    out without killing the stream.  Commands (``pull``/``skip``) map 1:1
    to result records; a timed-out command can be *abandoned* — its
    eventual result is dropped on arrival, which is exactly the accounting
    for "we gave up on that batch" (the stream item still gets consumed).
    """

    def __init__(self, it, name: str = "repro-retry-puller"):
        self._it = it
        self._cv = threading.Condition()
        self._cmds: collections.deque = collections.deque()
        self._results: collections.deque = collections.deque()
        self._outstanding = 0
        self._abandon = 0
        self._closed = False
        self._stopped = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def _run(self):
        while True:
            with self._cv:
                while not self._cmds and not self._closed:
                    self._cv.wait()
                if self._closed:
                    return
                cmd = self._cmds.popleft()
            if cmd == "pull":
                try:
                    rec = ("item", next(self._it))
                except StopIteration:
                    rec = ("stop", None)
                except BaseException as e:  # re-raised at the consumer
                    rec = ("error", e)
            else:  # "skip": consume-and-discard the current stream item
                skip = getattr(self._it, "skip_current", None)
                try:
                    if skip is not None:
                        skip()
                    else:
                        next(self._it)
                    rec = ("skipped", None)
                except StopIteration:
                    rec = ("stop", None)
                except BaseException as e:
                    # the batch is being abandoned anyway: a skip that
                    # raises still counts as disposed of
                    rec = ("skipped", e)
            stop = rec[0] == "stop"
            with self._cv:
                if stop or not self._abandon:
                    if stop and self._abandon:
                        self._abandon -= 1
                    self._results.append(rec)
                else:
                    self._abandon -= 1
                self._cv.notify_all()
            if stop:
                return  # iterator finished; nothing more to serve

    def pull(self, timeout: float | None):
        """Next item, waiting at most ``timeout`` for *this attempt*.  On
        timeout the pending pull is kept (a later attempt re-waits on it);
        raising ``_AttemptTimeout`` charges the attempt to the caller."""
        with self._cv:
            if self._stopped and not self._results:
                raise StopIteration
            if self._outstanding == 0:
                self._cmds.append("pull")
                self._outstanding += 1
                self._cv.notify_all()
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self._results:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise _AttemptTimeout()
                self._cv.wait(remaining)
            kind, payload = self._results.popleft()
            self._outstanding -= 1
            if kind == "item":
                return payload
            if kind == "stop":
                self._stopped = True
                raise StopIteration
            if kind == "error":
                raise payload
            raise RuntimeError(f"unexpected puller record {kind!r}")

    def skip(self, timeout: float | None) -> bool:
        """Dispose of the current stream item.  Returns True when the
        stream is known to have ended (nothing was consumed)."""
        with self._cv:
            if self._stopped:
                return True
            if self._outstanding:
                # the in-flight pull IS the current batch: drop its result
                self._abandon += 1
                self._outstanding -= 1
                return False
            self._cmds.append("skip")
            self._outstanding += 1
            self._cv.notify_all()
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self._results:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    # the skip itself wedged: abandon it too
                    self._abandon += 1
                    self._outstanding -= 1
                    return False
                self._cv.wait(remaining)
            kind, _ = self._results.popleft()
            self._outstanding -= 1
            if kind == "stop":
                self._stopped = True
                return True
            return False

    def close(self, timeout: float = 5.0) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout)
        if self._thread.is_alive():
            warnings.warn(
                f"{self._thread.name} did not join within {timeout}s; "
                "the source may be blocked outside our control",
                RuntimeWarning, stacklevel=2,
            )


_SKIPPED = object()


class RetryingSource(Source):
    """Bounded-retry wrapper: survive transient read errors, time out hung
    reads, quarantine invalid batches, and account for every item the
    stream gave up on.

    * ``TransientSourceError`` and per-attempt timeouts are retried up to
      ``max_retries`` times with exponential backoff
      (``backoff_s * 2**(attempt-1)``).
    * ``PermanentSourceError`` and exhausted retries follow
      ``on_exhausted``: ``"raise"`` (default) kills the stream with the
      original error; ``"skip"`` drops the batch, advances the source, and
      counts ``packets_dropped``.
    * With a ``validator``, delivered batches that fail validation are
      handed to the ``quarantine`` sink (counted as
      ``batches_quarantined`` + ``packets_dropped``) and the stream
      continues; without a quarantine they raise ``PoisonedBatchError``.
    * ``attempt_timeout_s`` moves pulls onto a dedicated thread
      (``repro-retry-puller``) so a hung read is charged as a failed
      attempt instead of wedging the pipeline — call ``close()`` (the
      engine does) to tear it down.

    Any other exception — including ``WorkerKilled`` — propagates
    untouched: retrying must never paper over faults it wasn't asked to
    survive.
    """

    def __init__(self, inner, *, max_retries: int = 3,
                 backoff_s: float = 0.0,
                 attempt_timeout_s: float | None = None,
                 on_exhausted: str = "raise",
                 validator: Callable | None = None,
                 quarantine: QuarantineSink | None = None,
                 counters: FaultCounters | None = None,
                 sleep: Callable = time.sleep):
        if on_exhausted not in ("raise", "skip"):
            raise ValueError(
                f"on_exhausted must be 'raise' or 'skip', "
                f"got {on_exhausted!r}"
            )
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.inner = inner
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.attempt_timeout_s = attempt_timeout_s
        self.on_exhausted = on_exhausted
        self.validator = validator
        self.quarantine = quarantine
        self.counters = counters if counters is not None else FaultCounters()
        self.packets_per_item = getattr(inner, "packets_per_item", None)
        self._sleep = sleep
        self._delivered_pos: list[int] = []
        self._live: _RetryIter | None = None

    def __iter__(self) -> "_RetryIter":
        it = iter(self.inner)
        puller = (None if self.attempt_timeout_s is None
                  else _TimeoutPuller(it))
        self._delivered_pos = []
        self._live = _RetryIter(self, it, puller)
        return self._live

    def delivered_pos(self, delivered_index: int) -> int:
        """Stream items consumed from the wrapped source by the time the
        ``delivered_index``-th item was handed out — skipped and
        quarantined batches included.  This is the exact cursor the engine
        checkpoints so a resumed run fast-forwards past everything this
        run disposed of, not just what it delivered."""
        return self._delivered_pos[delivered_index]

    def close(self) -> None:
        live, self._live = self._live, None
        if live is not None:
            live.close()


class _RetryIter:
    def __init__(self, src: RetryingSource, it, puller: _TimeoutPuller | None):
        self._src = src
        self._it = it
        self._puller = puller
        self._stream_pos = 0  # items consumed from the wrapped source
        self._exhausted = False

    def __iter__(self) -> "_RetryIter":
        return self

    def close(self) -> None:
        if self._puller is not None:
            self._puller.close()

    def __next__(self):
        src = self._src
        while True:
            if self._exhausted:
                raise StopIteration
            item = self._attempt_batch()
            if item is _SKIPPED:
                continue
            self._stream_pos += 1
            src._delivered_pos.append(self._stream_pos)
            return item

    def _pull_once(self):
        if self._puller is not None:
            return self._puller.pull(self._src.attempt_timeout_s)
        return next(self._it)

    def _attempt_batch(self):
        src = self._src
        attempts = 0
        while True:
            try:
                item = self._pull_once()
            except StopIteration:
                self._exhausted = True
                raise
            except TransientSourceError as e:
                retryable: Exception = e
            except _AttemptTimeout:
                retryable = SourceTimeoutError(
                    f"source read exceeded {src.attempt_timeout_s}s "
                    f"per attempt, {src.max_retries} retries used"
                )
            except PermanentSourceError as e:
                return self._give_up(e)
            else:
                if src.validator is not None:
                    reason = src.validator(item)
                    if reason is not None:
                        return self._quarantine_item(item, reason)
                return item
            attempts += 1
            if attempts > src.max_retries:
                return self._give_up(retryable)
            src.counters.add("retries")
            if src.backoff_s > 0:
                src._sleep(src.backoff_s * (2 ** (attempts - 1)))

    def _quarantine_item(self, item, reason: str):
        src = self._src
        index = self._stream_pos  # the item just consumed sits at this index
        self._stream_pos += 1
        src.counters.add("batches_quarantined")
        if src.packets_per_item:
            src.counters.add("packets_dropped", src.packets_per_item)
        if src.quarantine is None:
            raise PoisonedBatchError(
                f"stream batch {index} failed validation ({reason}) and no "
                "quarantine sink is attached"
            )
        src.quarantine.quarantine(index, item, reason)
        return _SKIPPED

    def _give_up(self, err: Exception):
        src = self._src
        if src.on_exhausted != "skip":
            raise err
        consumed = self._skip_stream_item()
        if consumed and src.packets_per_item:
            src.counters.add("packets_dropped", src.packets_per_item)
        return _SKIPPED

    def _skip_stream_item(self) -> bool:
        """Advance the wrapped source past the batch being given up on.
        Returns True if a stream item was consumed (or abandoned to be
        consumed); False if the source turned out to be exhausted."""
        if self._puller is not None:
            ended = self._puller.skip(self._src.attempt_timeout_s)
            if ended:
                self._exhausted = True
                return False
            self._stream_pos += 1
            return True
        skip = getattr(self._it, "skip_current", None)
        try:
            if skip is not None:
                consumed = skip()
            else:
                next(self._it)
                consumed = True
        except StopIteration:
            consumed = False
        if not consumed:
            self._exhausted = True
            return False
        self._stream_pos += 1
        return True


class FaultInjectingSink(Sink):
    """Wrap a sink; ``consume`` raises ``SinkWriteError`` once per planned
    ``sink`` fault index, before the wrapped sink sees the batch."""

    def __init__(self, inner: Sink, plan: FaultPlan,
                 counters: FaultCounters | None = None):
        self.inner = inner
        self.name = inner.name
        self.requires = inner.requires
        self.counters = counters if counters is not None else FaultCounters()
        self._fail_at = set(plan.sink_batches())

    def consume(self, index: int, outputs: dict) -> None:
        if index in self._fail_at:
            self._fail_at.discard(index)  # fire once; a redo succeeds
            self.counters.add("faults_injected")
            raise SinkWriteError(
                f"injected sink write failure at batch {index} "
                f"(sink {self.name!r})"
            )
        self.inner.consume(index, outputs)

    def finalize(self):
        return self.inner.finalize()

    def state_dict(self) -> dict:
        return self.inner.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.inner.load_state_dict(state)


@dataclasses.dataclass
class FaultTolerance:
    """Per-run fault-tolerance configuration handed to ``TrafficEngine.run``.

    ``plan`` injects faults (tests/benchmarks/chaos drills); the retry/
    timeout/skip/validation knobs configure survival.  ``sink_failures``
    selects whether a failing sink write kills the run (``"raise"``) or is
    counted and warned about (``"record"``) while the run continues.
    Owns the run's ``FaultCounters`` (reset at run start).
    """

    plan: FaultPlan | None = None
    max_retries: int = 3
    backoff_s: float = 0.0
    attempt_timeout_s: float | None = None
    on_exhausted: str = "raise"
    validate: bool = False
    quarantine: QuarantineSink | None = None
    quarantine_path: str | Path | None = None  # dead-letter file for the
    # auto-created quarantine sink (ignored when ``quarantine`` is given)
    sink_failures: str = "raise"  # "raise" | "record"
    counters: FaultCounters = dataclasses.field(default_factory=FaultCounters)

    def __post_init__(self):
        if self.sink_failures not in ("raise", "record"):
            raise ValueError(
                f"sink_failures must be 'raise' or 'record', "
                f"got {self.sink_failures!r}"
            )
        if self.quarantine_path is not None:
            # a dead-letter file is pointless without the validation pass
            # that feeds it
            self.validate = True
        if self.validate and self.quarantine is None:
            self.quarantine = QuarantineSink(path=self.quarantine_path)

    def wrap_source(self, source, *, cfg=None,
                    workload: str = "packets") -> RetryingSource:
        src = source
        if self.plan is not None and self.plan.source_specs():
            src = FaultInjectingSource(src, plan=self.plan,
                                       counters=self.counters)
        validator = None
        if self.validate:
            if cfg is None:
                raise ValueError("validate=True needs the engine cfg")
            validator = make_batch_validator(cfg, workload)
        return RetryingSource(
            src,
            max_retries=self.max_retries,
            backoff_s=self.backoff_s,
            attempt_timeout_s=self.attempt_timeout_s,
            on_exhausted=self.on_exhausted,
            validator=validator,
            quarantine=self.quarantine,
            counters=self.counters,
        )

    def wrap_sinks(self, sinks: Iterable[Sink]) -> list[Sink]:
        """Apply planned sink faults: the first real sink gets wrapped (one
        deterministic failure site; wrapping all of them would multiply
        every planned fault by the sink count)."""
        sinks = list(sinks)
        if self.plan is None or not self.plan.sink_batches():
            return sinks
        for i, s in enumerate(sinks):
            if not isinstance(s, QuarantineSink):
                sinks[i] = FaultInjectingSink(s, self.plan,
                                              counters=self.counters)
                break
        return sinks
