"""Unified telemetry for every execution policy.

Every policy — ``blocking``, ``double_buffered``, ``sharded`` — returns the
same ``EngineReport``, so Fig. 2 curves (pkt/s vs. mode) stay directly
comparable no matter which loop produced them.

Packet accounting follows ONE rule, shared by every consumer
(``packets_in_item``): a packet buffer's trailing axis is the (src, dst)
coordinate pair and every leading axis indexes packets, so a buffer counts
``prod(shape[:-1])`` packets.  A ``[W, n, 2]`` batch of W windows is
``W * n`` packets; a flat ``[n, 2]`` window is ``n``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any


def packets_in_item(item: Any, packets_per_item: int | None = None) -> int:
    """Packets carried by one source item, under the shared rule.

    An explicit ``packets_per_item`` (e.g. from a Source that knows its
    geometry) wins; otherwise the count is inferred as the product of every
    axis except the trailing coordinate axis.
    """
    if packets_per_item is not None:
        return packets_per_item
    shape = getattr(item, "shape", None)
    if shape is not None and len(shape) >= 2:
        return math.prod(shape[:-1])
    return 0


@dataclasses.dataclass
class EngineReport:
    """What a pipeline run measured — the paper's Figure-2 quantities.

    ``produce_s`` is time spent materializing/transferring input (the "IO"
    half: NIC DMA / host->device put); ``process_s`` is device build+merge+
    analytics time.  In ``double_buffered`` mode the two overlap, so their
    sum can exceed ``elapsed_s`` — that surplus *is* the overlap win.

    Async-dispatch policies (``async_pipelined``, ``sharded_pipelined``)
    change the ``process_s`` semantics: submissions do not block, so
    ``process_s`` is only the *exposed* device wait (wall-clock spent in
    ``block_until_ready``, including the end-of-stream drain), while
    ``overlap_s`` is head-of-line in-flight time hidden behind host work.
    By construction ``process_s + overlap_s <= elapsed_s``; their sum
    approximates the synchronous policies' ``process_s``.  ``max_in_flight``
    is the deepest ring of concurrently submitted batches observed (1 for
    the synchronous policies).  See DESIGN.md "Async dispatch & donation".

    ``producer_workers`` and ``submit_batches`` record the produce-path
    shape the run used (DESIGN.md "Producer pipeline"): N prefetch worker
    threads, and K source batches stacked per device dispatch.  With
    ``submit_batches=K > 1`` each ring slot holds one K-chunk, so
    ``max_in_flight`` counts *chunks* (up to K·max_in_flight source batches
    are in flight); per-batch outputs and their sink delivery order are
    unchanged.
    """

    batches: int = 0
    packets: int = 0
    elapsed_s: float = 0.0
    produce_s: float = 0.0
    process_s: float = 0.0
    results: list = dataclasses.field(default_factory=list)
    policy: str = ""
    merge_overflow: int = 0
    overlap_s: float = 0.0
    max_in_flight: int = 1
    producer_workers: int = 1
    submit_batches: int = 1
    # Fault-tolerance accounting (engine.faults).  Degraded runs must say
    # exactly what they survived and what they skipped: ``packets`` counts
    # only delivered batches (the single packets_in_item rule), while
    # ``packets_dropped`` counts what retry-exhaustion/quarantine gave up.
    retries: int = 0
    batches_quarantined: int = 0
    packets_dropped: int = 0
    faults_injected: int = 0
    sink_write_failures: int = 0
    # Checkpoint/resume accounting: checkpoints written this run, and the
    # global measured-batch index the run resumed at (0 = cold start).
    # A resumed report folds the checkpointed batches/packets/counters in,
    # so it describes the *logical* run end-to-end.
    checkpoints_written: int = 0
    resumed_from: int = 0

    @property
    def packets_per_second(self) -> float:
        return self.packets / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def summary(self) -> str:
        """One-line report in the Fig.-2 style.  Depth and overlap always
        print — an async run at depth 1 still has exposed-wait ``process_s``
        semantics, and the line must carry the cue to read it that way.
        Fault/resume accounting appends only when present, keeping clean
        runs' lines unchanged."""
        line = (
            f"[{self.policy or 'pipeline'}] {self.packets:,} packets, "
            f"{self.elapsed_s:.2f}s -> {self.packets_per_second:,.0f} pkt/s "
            f"(produce {self.produce_s:.2f}s / process {self.process_s:.2f}s, "
            f"overlap {self.overlap_s:.2f}s @ depth {self.max_in_flight}, "
            f"overflow {self.merge_overflow})"
        )
        if (self.retries or self.batches_quarantined or self.packets_dropped
                or self.faults_injected or self.sink_write_failures):
            line += (
                f" [faults {self.faults_injected}: retries {self.retries}, "
                f"quarantined {self.batches_quarantined}, dropped "
                f"{self.packets_dropped:,} pkts, sink failures "
                f"{self.sink_write_failures}]"
            )
        if self.checkpoints_written or self.resumed_from:
            line += (
                f" [ckpt {self.checkpoints_written} written, resumed at "
                f"batch {self.resumed_from}]"
            )
        return line


# Historical name: ``core.stream`` called this StreamReport.  The engine is
# the home now; ``repro.core.stream`` re-exports it for compatibility.
StreamReport = EngineReport
