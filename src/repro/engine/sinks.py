"""Pluggable sinks: what happens to each processed batch.

A Sink declares which stage-graph outputs it needs (``requires``) — the
engine unions these into the graph's output set, so e.g. attaching a
``MatrixRetention`` sink is what makes the jitted step return the merged
matrix at all.  ``consume`` is called once per measured batch, inside the
pipeline loop, so implementations should only append/accumulate; expensive
host work belongs in ``finalize``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core import analytics

# Stats keys that add across batches; the rest are running maxima except the
# histograms, which also add.
_SUM_KEYS = ("valid_packets", "unique_links", "unique_sources",
             "unique_destinations")
_HIST_SUFFIX = "_hist"


class Sink:
    """Base sink; subclasses set ``requires`` and override the hooks."""

    name = "sink"
    requires: tuple[str, ...] = ("stats",)

    def consume(self, index: int, outputs: dict) -> None:
        raise NotImplementedError

    def finalize(self) -> Any:
        return None


class StatsAccumulator(Sink):
    """Accumulate per-batch analytics into totals + the per-batch trace.

    ``unique_*`` totals are per-batch sums (an address active in two batches
    counts twice — the paper's windows are disjoint in time, so that is the
    intended semantics, not double counting).
    """

    name = "stats"
    requires = ("stats", "merge_overflow")

    def __init__(self):
        self.per_batch: list[dict] = []
        self.overflow: list = []

    def consume(self, index: int, outputs: dict) -> None:
        self.per_batch.append(outputs["stats"])
        self.overflow.append(outputs["merge_overflow"])

    def finalize(self) -> dict:
        if not self.per_batch:
            return {"batches": 0}
        host = [
            {k: np.asarray(v) for k, v in jax.device_get(s).items()}
            for s in self.per_batch
        ]
        totals: dict[str, Any] = {"batches": len(host)}
        for k in host[0]:
            stacked = np.stack([s[k] for s in host])
            if k in _SUM_KEYS or k.endswith(_HIST_SUFFIX):
                totals[k] = stacked.sum(axis=0)
            else:
                totals[k] = stacked.max(axis=0)
        totals["merge_overflow"] = int(
            np.sum([np.asarray(o) for o in self.overflow])
        )
        totals["per_batch"] = host
        return totals


@dataclasses.dataclass
class TopKHeavyHitters(Sink):
    """Global top-k links by packet count, merged across batches.

    Per batch it takes the device top-k candidates from the merged matrix;
    finalize sums candidate counts per link and reports the global top-k.
    Exact whenever a true global heavy hitter is in its batch's top-k —
    guaranteed for k >= per-batch distinct heavy links, the usual case.
    """

    k: int = 10

    name = "top_k"
    requires = ("matrix",)

    def __post_init__(self):
        self._counts: dict[tuple[int, int], int] = {}

    def consume(self, index: int, outputs: dict) -> None:
        m = outputs["matrix"]
        rows, cols, counts = analytics.top_k_heavy_hitters(m, self.k)
        rows, cols, counts = jax.device_get((rows, cols, counts))
        for r, c, v in zip(rows, cols, counts):
            if v <= 0:
                continue
            key = (int(r), int(c))
            self._counts[key] = self._counts.get(key, 0) + int(v)

    def finalize(self) -> list[tuple[tuple[int, int], int]]:
        ranked = sorted(self._counts.items(), key=lambda kv: -kv[1])
        return ranked[: self.k]


@dataclasses.dataclass
class MatrixRetention(Sink):
    """Keep the last ``max_keep`` merged batch matrices (on host)."""

    max_keep: int = 8
    device: bool = False  # True: keep device arrays (no transfer)

    name = "matrices"
    requires = ("matrix",)

    def __post_init__(self):
        self.matrices: list = []

    def consume(self, index: int, outputs: dict) -> None:
        m = outputs["matrix"]
        if not self.device:
            m = jax.device_get(m)
        self.matrices.append(m)
        if len(self.matrices) > self.max_keep:
            self.matrices.pop(0)

    def finalize(self) -> list:
        return self.matrices
