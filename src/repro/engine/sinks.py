"""Pluggable sinks: what happens to each processed batch.

A Sink declares which stage-graph outputs it needs (``requires``) — the
engine unions these into the graph's output set, so e.g. attaching a
``MatrixRetention`` sink is what makes the jitted step return the merged
matrix at all.  ``consume`` is called once per measured batch, inside the
pipeline loop, so implementations should only append/accumulate; expensive
host work belongs in ``finalize``.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.core import analytics
from repro.data.packets import PcapLite

# Stats keys that add across batches; the rest are running maxima except the
# histograms, which also add.
_SUM_KEYS = ("valid_packets", "unique_links", "unique_sources",
             "unique_destinations")
_HIST_SUFFIX = "_hist"


class Sink:
    """Base sink; subclasses set ``requires`` and override the hooks."""

    name = "sink"
    requires: tuple[str, ...] = ("stats",)

    def consume(self, index: int, outputs: dict) -> None:
        raise NotImplementedError

    def finalize(self) -> Any:
        return None

    def close(self) -> None:
        """Release OS resources (file handles, sockets). Idempotent.

        The engine calls this on *every* exit from a run — including
        failure paths (source error, ``WorkerKilled``, sink-write
        failure) — so a crashed run leaks no fds.  ``finalize`` of a
        file-backed sink should itself close, making a later ``close``
        a no-op; ``close`` without ``finalize`` must still leave any
        partially-written file in a readable state.
        """

    # -- checkpointing -------------------------------------------------------
    # Engine checkpoints serialize every attached sink's state so a resumed
    # run finalizes to bit-identical results.  State must be host data
    # (numpy / python scalars / str) nested in dicts/lists/tuples — the
    # portable checkpoint encoding (checkpoint.serialization) handles the
    # rest.  A sink that cannot round-trip must raise, not silently resume
    # empty: losing accumulated windows would be lying about coverage.

    def state_dict(self) -> dict:
        raise NotImplementedError(
            f"sink {self.name!r} does not support checkpointing "
            "(no state_dict)"
        )

    def load_state_dict(self, state: dict) -> None:
        raise NotImplementedError(
            f"sink {self.name!r} does not support checkpointing "
            "(no load_state_dict)"
        )


class StatsAccumulator(Sink):
    """Accumulate per-batch analytics into totals + the per-batch trace.

    ``unique_*`` totals are per-batch sums (an address active in two batches
    counts twice — the paper's windows are disjoint in time, so that is the
    intended semantics, not double counting).
    """

    name = "stats"
    requires = ("stats", "merge_overflow")

    def __init__(self):
        self.per_batch: list[dict] = []
        self.overflow: list = []

    def consume(self, index: int, outputs: dict) -> None:
        self.per_batch.append(outputs["stats"])
        self.overflow.append(outputs["merge_overflow"])

    def finalize(self) -> dict:
        if not self.per_batch:
            return {"batches": 0}
        host = [
            {k: np.asarray(v) for k, v in jax.device_get(s).items()}
            for s in self.per_batch
        ]
        totals: dict[str, Any] = {"batches": len(host)}
        for k in host[0]:
            stacked = np.stack([s[k] for s in host])
            if k in _SUM_KEYS or k.endswith(_HIST_SUFFIX):
                totals[k] = stacked.sum(axis=0)
            else:
                totals[k] = stacked.max(axis=0)
        totals["merge_overflow"] = int(
            np.sum([np.asarray(o) for o in self.overflow])
        )
        totals["per_batch"] = host
        return totals

    def state_dict(self) -> dict:
        return {
            "per_batch": [
                {k: np.asarray(v) for k, v in jax.device_get(s).items()}
                for s in self.per_batch
            ],
            "overflow": [int(np.asarray(o)) for o in self.overflow],
        }

    def load_state_dict(self, state: dict) -> None:
        # restored rows are host dicts; finalize's device_get is a no-op on
        # them, so mixing restored + freshly-consumed device rows is fine
        self.per_batch = list(state["per_batch"])
        self.overflow = [int(o) for o in state["overflow"]]


@dataclasses.dataclass
class TopKHeavyHitters(Sink):
    """Global top-k links by packet count, merged across batches.

    Per batch it takes the device top-k candidates from the merged matrix;
    finalize sums candidate counts per link and reports the global top-k.
    Exact whenever a true global heavy hitter is in its batch's top-k —
    guaranteed for k >= per-batch distinct heavy links, the usual case.
    """

    k: int = 10

    name = "top_k"
    requires = ("matrix",)

    def __post_init__(self):
        self._counts: dict[tuple[int, int], int] = {}

    def consume(self, index: int, outputs: dict) -> None:
        m = outputs["matrix"]
        rows, cols, counts = analytics.top_k_heavy_hitters(m, self.k)
        rows, cols, counts = jax.device_get((rows, cols, counts))
        for r, c, v in zip(rows, cols, counts):
            if v <= 0:
                continue
            key = (int(r), int(c))
            self._counts[key] = self._counts.get(key, 0) + int(v)

    def finalize(self) -> list[tuple[tuple[int, int], int]]:
        ranked = sorted(self._counts.items(), key=lambda kv: -kv[1])
        return ranked[: self.k]

    def state_dict(self) -> dict:
        return {"counts": [[r, c, v]
                           for (r, c), v in self._counts.items()]}

    def load_state_dict(self, state: dict) -> None:
        self._counts = {(int(r), int(c)): int(v)
                        for r, c, v in state["counts"]}


@dataclasses.dataclass
class MatrixRetention(Sink):
    """Keep the last ``max_keep`` merged batch matrices (on host).

    ``key`` selects which stage output to retain — ``"matrix"`` (default) or
    ``"byte_matrix"`` for the flow path's byte-payload matrix.  Non-default
    keys report under the key's own name so two retention sinks can coexist.
    """

    max_keep: int = 8
    device: bool = False  # True: keep device arrays (no transfer)
    key: str = "matrix"

    name = "matrices"
    requires = ("matrix",)

    def __post_init__(self):
        self.matrices: list = []
        if self.key != "matrix":
            self.requires = (self.key,)
            self.name = self.key

    def consume(self, index: int, outputs: dict) -> None:
        m = outputs[self.key]
        if not self.device:
            m = jax.device_get(m)
        self.matrices.append(m)
        if len(self.matrices) > self.max_keep:
            self.matrices.pop(0)

    def finalize(self) -> list:
        return self.matrices

    def state_dict(self) -> dict:
        out = []
        for m in self.matrices:
            h = jax.device_get(m)
            out.append({
                "rows": np.asarray(h.rows),
                "cols": np.asarray(h.cols),
                "vals": np.asarray(h.vals),
                "nnz": np.asarray(h.nnz),
                "nrows": int(h.nrows),
                "ncols": int(h.ncols),
            })
        return {"matrices": out}

    def load_state_dict(self, state: dict) -> None:
        from repro.core.hypersparse import HypersparseMatrix

        self.matrices = [
            HypersparseMatrix(
                rows=d["rows"], cols=d["cols"], vals=d["vals"],
                nnz=d["nnz"], nrows=int(d["nrows"]), ncols=int(d["ncols"]),
            )
            for d in state["matrices"]
        ]


@dataclasses.dataclass
class AnomalySink(Sink):
    """Flag anomalous windows by z-scoring per-window fan-out histograms.

    Consumes the ``fanout_hist`` output ([W, HIST_BINS] per batch — the
    engine auto-appends the ``fanout`` stage when this sink is attached) and
    accumulates one histogram row per window across the whole run.  Finalize
    z-scores each histogram bin against its across-window mean/std; a
    window's score is its largest absolute bin z-score, and windows at or
    above ``threshold`` are flagged.  Scans/sweeps concentrate mass in high
    fan-out bins that benign windows never populate, which is exactly the
    deviation this measures (per-window streaming detection in the style of
    Jones et al., "GraphBLAS on the Edge").

    Note the population z-score over N windows is bounded by sqrt(N-1):
    with fewer than ~11 windows the default threshold of 3.0 is
    unreachable — lower it (or ingest more windows) accordingly.
    """

    threshold: float = 3.0

    name = "anomaly"
    requires = ("fanout_hist",)

    def __post_init__(self):
        self._hists: list = []

    def consume(self, index: int, outputs: dict) -> None:
        self._hists.append(outputs["fanout_hist"])

    def finalize(self) -> dict:
        if not self._hists:
            return {"windows": 0, "scores": np.zeros((0,)), "flagged": [],
                    "threshold": self.threshold}
        hists = np.concatenate(
            [np.asarray(jax.device_get(h)) for h in self._hists], axis=0
        ).astype(np.float64)
        mean = hists.mean(axis=0)
        std = hists.std(axis=0)
        z = np.where(std > 0, (hists - mean) / np.where(std > 0, std, 1.0),
                     0.0)
        scores = np.abs(z).max(axis=1)
        flagged = [int(i) for i in np.nonzero(scores >= self.threshold)[0]]
        return {
            "windows": int(hists.shape[0]),
            "scores": scores,
            "flagged": flagged,
            "threshold": self.threshold,
        }

    def state_dict(self) -> dict:
        return {"hists": [np.asarray(jax.device_get(h))
                          for h in self._hists]}

    def load_state_dict(self, state: dict) -> None:
        self._hists = list(state["hists"])


@dataclasses.dataclass
class PcapLiteWriterSink(Sink):
    """Write the anonymized stream back out as a replayable pcap-lite file.

    ``key="packets"`` (default) captures the post-anonymization packet
    buffers; ``key="flows"`` captures the flow path's anonymized records,
    keeping only the (src, dst) columns — one pair per flow.  Either way the
    output re-ingests through ``PcapLiteSource`` (with anonymization "none")
    to the same matrices the producing run built, which is the
    writer/reader round-trip contract the sink tests pin down.
    """

    path: str | Path = "anonymized.pcl"
    key: str = "packets"
    compress: bool = False

    name = "pcap"

    def __post_init__(self):
        self.requires = (self.key,)
        self._fh = None
        self._count = 0

    # Writes are incremental (a daemon's stream must not accumulate in
    # memory): the file is opened lazily with a zero-count header, raw
    # uint32 pairs stream in per batch, and close() back-patches the
    # header count — so even a failure-path close leaves a readable,
    # uncompressed capture of everything consumed so far.  If
    # ``compress`` is set, finalize() rewrites the completed raw file
    # as one compressed blob (compression is a finalize step, not a
    # streaming one, so crash/resume can truncate to a byte cursor).

    def _ensure_open(self):
        if self._fh is None or self._fh.closed:
            from repro.checkpoint.framelog import track_file

            self._fh = track_file(open(self.path, "w+b"))
            self._write_header()

    def _write_header(self):
        from repro.data.packets import MAGIC, VERSION
        import struct

        self._fh.seek(0)
        self._fh.write(MAGIC + struct.pack("<HHQ", VERSION, 0, self._count))

    def consume(self, index: int, outputs: dict) -> None:
        buf = np.asarray(jax.device_get(outputs[self.key]))
        pairs = np.ascontiguousarray(
            buf.reshape(-1, buf.shape[-1])[:, :2], dtype=np.uint32
        )
        self._ensure_open()
        self._fh.seek(0, 2)
        self._fh.write(pairs.tobytes())
        self._count += int(pairs.shape[0])

    def close(self) -> None:
        if self._fh is not None and not self._fh.closed:
            self._write_header()
            self._fh.close()
        self._fh = None

    def finalize(self) -> dict:
        self._ensure_open()  # zero-batch runs still produce a valid file
        self.close()
        if self.compress:
            PcapLite.write(self.path, PcapLite.read(self.path),
                           compress=True)
        return {"path": str(self.path), "packets": self._count}

    def state_dict(self) -> dict:
        if self._fh is not None and not self._fh.closed:
            self._fh.flush()
            offset = self._fh.seek(0, 2)
        else:
            offset = 0
        return {"count": self._count, "offset": int(offset)}

    def load_state_dict(self, state: dict) -> None:
        from repro.checkpoint.framelog import track_file

        self.close()
        self._count = int(state["count"])
        offset = int(state["offset"])
        if offset == 0:
            return
        size = Path(self.path).stat().st_size if Path(self.path).exists() else 0
        if size < offset:
            raise ValueError(
                f"pcap-lite output {self.path} is {size} bytes, shorter "
                f"than the checkpoint cursor {offset}: cannot resume"
            )
        self._fh = track_file(open(self.path, "r+b"))
        self._fh.truncate(offset)
        self._fh.seek(offset)
