"""Pluggable packet sources: where batches of traffic windows come from.

A Source is an iterable of host ``uint32`` packet buffers shaped
``[windows_per_batch, window_size, 2]`` (trailing axis = (src, dst)), plus a
``packets_per_item`` hint for rate accounting (see ``telemetry``).  The three
built-ins mirror the paper's traffic generators:

* ``SyntheticSource(kind="uniform")`` — wire-rate random frames (pktgen);
* ``SyntheticSource(kind="zipf")``    — heavy-tailed CAIDA-style traffic;
* ``PcapLiteSource``                  — capture replay (dpdk-burst-replay),
  wrapping ``data.packets.PcapLite``.

New formats plug in here: subclass Source (or hand any iterable to
``as_source``) and every execution policy and sink works unchanged.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterable, Iterator

import numpy as np

from repro.data.flows import FLOW_WIDTH, eve_read, flow_batches
from repro.data.packets import PcapLite, traffic_batches


# Spec strings that resolve to synthetic generators (everything else that is
# a str/Path is treated as a file to replay).  The single authority for
# "is this spec synthetic?" — callers deciding e.g. whether a warmup batch
# can be added must consult this, not restate the list.
SYNTHETIC_SPECS = {"uniform": "uniform", "zipf": "zipf",
                   "flow": "uniform", "flow-zipf": "zipf"}


class Source:
    """Iterable of host packet buffers; subclasses set ``packets_per_item``."""

    packets_per_item: int | None = None

    def __iter__(self) -> Iterator[np.ndarray]:
        raise NotImplementedError


@dataclasses.dataclass
class SyntheticSource(Source):
    """The paper's synthetic workloads (``data.packets.traffic_batches``)."""

    kind: str = "uniform"  # uniform | zipf
    seed: int = 0
    n_batches: int = 8
    windows_per_batch: int = 64
    window_size: int = 1 << 17

    def __post_init__(self):
        self.packets_per_item = self.windows_per_batch * self.window_size

    def __iter__(self) -> Iterator[np.ndarray]:
        return traffic_batches(
            seed=self.seed,
            n_batches=self.n_batches,
            windows_per_batch=self.windows_per_batch,
            window_size=self.window_size,
            kind=self.kind,
        )


@dataclasses.dataclass
class PcapLiteSource(Source):
    """Replay a pcap-lite capture as window batches (trailing partial batch
    is dropped, like a replayer stopping mid-burst)."""

    path: str | Path = ""
    windows_per_batch: int = 64
    window_size: int = 1 << 17

    def __post_init__(self):
        self.packets_per_item = self.windows_per_batch * self.window_size

    def __iter__(self) -> Iterator[np.ndarray]:
        pkts = PcapLite.read(self.path)
        per_batch = self.packets_per_item
        for i in range(0, len(pkts) - per_batch + 1, per_batch):
            yield pkts[i : i + per_batch].reshape(
                self.windows_per_batch, self.window_size, 2
            )


@dataclasses.dataclass
class SyntheticFlowSource(Source):
    """Synthetic Suricata-style flow records ([W, n, 5] uint32 batches:
    src, dst, bytes, packets, flags — see ``data.flows``).  For flow
    workloads ``packets_per_item`` counts *records*, so EngineReport rates
    read as flows/s."""

    kind: str = "uniform"  # uniform | zipf
    seed: int = 0
    n_batches: int = 8
    windows_per_batch: int = 64
    window_size: int = 1 << 17  # flow records per window

    def __post_init__(self):
        self.packets_per_item = self.windows_per_batch * self.window_size

    def __iter__(self) -> Iterator[np.ndarray]:
        return flow_batches(
            seed=self.seed,
            n_batches=self.n_batches,
            windows_per_batch=self.windows_per_batch,
            window_size=self.window_size,
            kind=self.kind,
        )


@dataclasses.dataclass
class SuricataFlowSource(Source):
    """Replay flow records from an EVE-JSON(-lite) file as window batches
    (non-flow events are skipped; the trailing partial batch is dropped,
    mirroring ``PcapLiteSource``)."""

    path: str | Path = ""
    windows_per_batch: int = 64
    window_size: int = 1 << 17

    def __post_init__(self):
        self.packets_per_item = self.windows_per_batch * self.window_size

    def __iter__(self) -> Iterator[np.ndarray]:
        flows = eve_read(self.path)
        per_batch = self.packets_per_item
        for i in range(0, len(flows) - per_batch + 1, per_batch):
            yield flows[i : i + per_batch].reshape(
                self.windows_per_batch, self.window_size, FLOW_WIDTH
            )


@dataclasses.dataclass
class IterableSource(Source):
    """Adapter for a plain iterable of buffers (rate inferred per item)."""

    it: Iterable = ()
    packets_per_item: int | None = None

    def __iter__(self) -> Iterator:
        return iter(self.it)


def as_source(
    spec,
    *,
    window_size: int,
    windows_per_batch: int,
    n_batches: int = 8,
    seed: int = 0,
    workload: str = "packets",
) -> Source:
    """Resolve a source spec: a Source passes through; ``"uniform"``/
    ``"zipf"`` build a SyntheticSource (or SyntheticFlowSource under the
    ``"flow"`` workload); a path builds a PcapLiteSource (packets) or a
    SuricataFlowSource (flows); any other iterable is wrapped."""
    if isinstance(spec, Source):
        return spec
    if isinstance(spec, (str, Path)):
        if workload == "flow":
            if spec in SYNTHETIC_SPECS:
                return SyntheticFlowSource(
                    kind=SYNTHETIC_SPECS[str(spec)], seed=seed,
                    n_batches=n_batches,
                    windows_per_batch=windows_per_batch,
                    window_size=window_size,
                )
            return SuricataFlowSource(
                path=spec, windows_per_batch=windows_per_batch,
                window_size=window_size,
            )
        if spec in ("uniform", "zipf"):
            return SyntheticSource(
                kind=str(spec), seed=seed, n_batches=n_batches,
                windows_per_batch=windows_per_batch, window_size=window_size,
            )
        return PcapLiteSource(
            path=spec, windows_per_batch=windows_per_batch,
            window_size=window_size,
        )
    if isinstance(spec, Iterable):
        return IterableSource(it=spec)
    raise TypeError(f"cannot interpret source spec: {spec!r}")
