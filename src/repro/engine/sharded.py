"""The sharded execution path: mesh-parallel windows, exact global merge.

Windows shard across the mesh; each device builds+merges its local windows,
then entries are exchanged by row-block ``all_to_all`` so each device owns a
``2^32 / n_dev`` slice of source-address space (the 2D decomposition in
DESIGN.md).  Exact distinct-source / distinct-link counts fall out because
every row lives on exactly one owner.

Lifted out of ``launch/ingest.py`` so the same step serves the ``sharded``
execution policy, the launcher CLI, and the multi-device tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import analytics
from repro.core.build import matrix_build
from repro.core.hypersparse import SENTINEL
from repro.core.window import WindowConfig, process_batch, process_flow_batch
from repro.distributed import sharding as shrules


def route_entries(rows, cols, vals, valid, n_dev: int, cap_out: int):
    """Bucket entries by owner device (row-block) into [n_dev, cap_out]."""
    bits = int(np.log2(n_dev))
    if bits == 0:
        owner = jnp.zeros(rows.shape, jnp.int32)
    else:
        owner = (rows >> jnp.uint32(32 - bits)).astype(jnp.int32)
    owner = jnp.where(valid, owner, n_dev)
    # rank within each owner bucket (stable by entry order)
    order = jnp.argsort(owner, stable=True)
    so = owner[order]
    n = rows.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    run_start = jax.lax.cummax(jnp.where(first, iota, 0), axis=0)
    rank = iota - run_start
    keep = rank < cap_out
    slot = jnp.where(keep, so * cap_out + rank, n_dev * cap_out)

    def scatter(x, fill):
        buf = jnp.full((n_dev * cap_out,), fill, x.dtype)
        return buf.at[slot].set(x[order], mode="drop").reshape(
            n_dev, cap_out
        )

    kept_valid = (keep & (so < n_dev)).sum().astype(jnp.int32)
    overflow = valid.sum().astype(jnp.int32) - kept_valid
    return (
        scatter(rows, SENTINEL),
        scatter(cols, SENTINEL),
        scatter(vals, jnp.zeros((), vals.dtype)),
        overflow,
    )


def make_exact_ingest_step(mesh, cfg: WindowConfig, *,
                           route_capacity_factor: float = 2.0,
                           workload: str = "packets"):
    """shard_map step: local builds -> all_to_all row-block exchange ->
    owner-local dedup -> exact global analytics.

    ``workload="flow"`` takes [w_local, n, 5] flow records instead of
    [w_local, n, 2] packets: addresses anonymize, packet-count payloads
    accumulate with ``plus``, and the routed entries carry the values —
    everything downstream of the local merge is payload-agnostic, so the
    same exchange/dedup/psum machinery stays exact.
    """
    axes = shrules.all_axes(mesh)
    flat = axes if len(axes) > 1 else axes[0]
    n_dev = mesh.size

    def shard_fn(windows_local):
        if workload == "flow":
            # same anonymize+build+merge as the stage graph's flow path
            merged, ovf = process_flow_batch(windows_local, cfg)
        else:
            merged, ovf = process_batch(windows_local, cfg)[0::2]
        cap = merged.capacity
        cap_out = int(cap * route_capacity_factor / n_dev) + 8
        r, c, v, route_ovf = route_entries(
            merged.rows, merged.cols, merged.vals, merged.valid_mask(),
            n_dev, cap_out,
        )
        # exchange: device d sends bucket j to device j
        if n_dev > 1:
            r = jax.lax.all_to_all(r, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
            c = jax.lax.all_to_all(c, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
            v = jax.lax.all_to_all(v, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
        # owner-local dedup of everything received (rows all in my block)
        r, c, v = r.reshape(-1), c.reshape(-1), v.reshape(-1)
        n_valid = (r != SENTINEL).sum().astype(jnp.int32)
        # move sentinels to the back for the build contract
        order = jnp.argsort(r == SENTINEL, stable=True)
        mine = matrix_build(r[order], c[order], v[order],
                            n_valid=n_valid, dtype=v.dtype)
        local = analytics.window_stats(mine)
        out = {
            # row-keyed stats are exact under row ownership
            "valid_packets": jax.lax.psum(local["valid_packets"], axes),
            "unique_links": jax.lax.psum(mine.nnz, axes),
            "unique_sources": jax.lax.psum(local["unique_sources"], axes),
            "max_packets_per_link": jax.lax.pmax(
                local["max_packets_per_link"], axes),
            "max_source_packets": jax.lax.pmax(
                local["max_source_packets"], axes),
            "max_source_fanout": jax.lax.pmax(
                local["max_source_fanout"], axes),
            "src_packet_hist": jax.lax.psum(local["src_packet_hist"], axes),
            "src_fanout_hist": jax.lax.psum(local["src_fanout_hist"], axes),
            "merge_overflow": jax.lax.psum(ovf + route_ovf, axes),
        }
        return out

    return shrules.shard_map(shard_fn, mesh=mesh, in_specs=P(flat),
                             out_specs=P(), check_rep=False)
