"""Declarative stage graph: anonymize -> build -> merge -> analytics.

A Stage is a named, pure function over a context dict of named arrays
(``packets``, ``flows``, ``windows``, ``matrix``, ...).  A StageGraph is an
ordered selection of stages, validated at construction (every stage's
``requires`` must be provided upstream, every requested output must exist)
and compiled to a single jitted device function ``input batch -> outputs
dict`` (``input_key`` names what the batch is: ``packets`` for [W, n, 2]
pairs, ``flows`` for [W, n, 5] Suricata-style records).

Two built-in paths share the registry: the paper's packet path
(``DEFAULT_STAGES``) and the value-carrying flow path (``FLOW_STAGES``:
anonymize_flows -> build_flow -> merge_flow -> analytics, where byte and
packet payloads accumulate under the ``plus`` semiring).  New stages
register once and become available to every source/sink/policy
combination.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import analytics
from repro.core import anonymize as anon
from repro.core.build import build_window
from repro.core.window import (
    WindowConfig,
    anonymize_flows,
    build_flow_windows,
    merge_tree,
)
from repro.data.flows import FLOW_BYTES, FLOW_PKTS


@dataclasses.dataclass(frozen=True)
class Stage:
    """One named pipeline step: ctx subset in -> new ctx entries out."""

    name: str
    requires: tuple[str, ...]
    provides: tuple[str, ...]
    fn: Callable[[dict, WindowConfig], dict]


STAGE_REGISTRY: dict[str, Stage] = {}


def register_stage(name: str, requires: Sequence[str],
                   provides: Sequence[str]):
    """Register a stage fn(ctx, cfg) -> dict of provided entries."""

    def deco(fn):
        STAGE_REGISTRY[name] = Stage(
            name=name, requires=tuple(requires), provides=tuple(provides),
            fn=fn,
        )
        return fn

    return deco


@register_stage("anonymize", requires=("packets",), provides=("packets",))
def _anonymize(ctx, cfg):
    # Both schemes are elementwise bijections over uint32, so they apply to
    # the whole [W, n, 2] batch at once (== vmap over windows).
    return {
        "packets": anon.anonymize_packets(
            ctx["packets"], cfg.anonymization_key, cfg.anonymization
        )
    }


@register_stage("build", requires=("packets",), provides=("windows",))
def _build(ctx, cfg):
    dtype = jnp.dtype(cfg.val_dtype)
    windows = jax.vmap(
        lambda p: build_window(p, dtype=dtype, use_kernel=cfg.build_kernel)
    )(ctx["packets"])
    return {"windows": windows}


@register_stage("merge", requires=("windows",),
                provides=("matrix", "merge_overflow"))
def _merge(ctx, cfg):
    merged, overflow = merge_tree(ctx["windows"], cfg)
    return {"matrix": merged, "merge_overflow": overflow}


@register_stage("analytics", requires=("matrix",), provides=("stats",))
def _analytics(ctx, cfg):
    return {"stats": analytics.window_stats(ctx["matrix"])}


@register_stage("window_analytics", requires=("windows",),
                provides=("window_stats",))
def _window_analytics(ctx, cfg):
    return {"window_stats": analytics.window_stats_batched(ctx["windows"])}


@register_stage("fanout", requires=("windows",), provides=("fanout_hist",))
def _fanout(ctx, cfg):
    # Per-window [W, HIST_BINS] source fan-out histograms — the feature the
    # AnomalySink z-scores.  Works for both workloads: "windows" is the
    # pre-merge window-matrix stack whether built from packets or flows.
    return {"fanout_hist": analytics.src_fanout_hist_batched(ctx["windows"])}


# -- the value-carrying flow path (Suricata flow records) -------------------

@register_stage("anonymize_flows", requires=("flows",), provides=("flows",))
def _anonymize_flows(ctx, cfg):
    # Only the address columns are anonymized; byte/packet/flag payloads
    # ride along untouched (anonymization must preserve the values whose
    # conservation the flow tests assert).
    return {"flows": anonymize_flows(ctx["flows"], cfg)}


@register_stage("build_flow", requires=("flows",),
                provides=("windows", "byte_windows"))
def _build_flow(ctx, cfg):
    flows = ctx["flows"]
    return {
        "windows": build_flow_windows(flows, cfg, value_col=FLOW_PKTS),
        "byte_windows": build_flow_windows(flows, cfg,
                                           value_col=FLOW_BYTES),
    }


@register_stage("merge_flow", requires=("windows", "byte_windows"),
                provides=("matrix", "byte_matrix", "merge_overflow",
                          "byte_merge_overflow"))
def _merge_flow(ctx, cfg):
    # Byte overflow is reported separately so that when no sink asks for the
    # byte matrix, XLA dead-code-eliminates the whole byte build+merge.
    merged, overflow = merge_tree(ctx["windows"], cfg)
    byte_merged, byte_overflow = merge_tree(ctx["byte_windows"], cfg)
    return {"matrix": merged, "merge_overflow": overflow,
            "byte_matrix": byte_merged,
            "byte_merge_overflow": byte_overflow}


@register_stage("byte_analytics", requires=("byte_matrix",),
                provides=("byte_stats",))
def _byte_analytics(ctx, cfg):
    return {"byte_stats": analytics.window_stats(ctx["byte_matrix"])}


DEFAULT_STAGES = ("anonymize", "build", "merge", "analytics")
FLOW_STAGES = ("anonymize_flows", "build_flow", "merge_flow", "analytics")
DEFAULT_OUTPUTS = ("stats", "merge_overflow")
WORKLOAD_STAGES = {"packets": DEFAULT_STAGES, "flow": FLOW_STAGES}
WORKLOAD_INPUT_KEY = {"packets": "packets", "flow": "flows"}


def extend_stages_for(stages, required, input_key: str = "packets"):
    """Append registered stages able to provide missing required outputs.

    This is how the engine derives the graph from what the sinks need: e.g.
    attaching an ``AnomalySink`` (requires ``fanout_hist``) auto-appends the
    ``fanout`` stage.  Resolution is greedy over the registry; anything
    still unprovided is left for StageGraph construction to reject with its
    usual diagnostic.
    """
    names = list(stages)
    available = {input_key}
    for s in names:
        available |= set(StageGraph._resolve(s).provides)
    for key in required:
        if key in available:
            continue
        for cand in STAGE_REGISTRY.values():
            if key in cand.provides and set(cand.requires) <= available:
                names.append(cand.name)
                available |= set(cand.provides)
                break
    return tuple(names)


class StageGraph:
    """Validated, jitted composition of registered stages."""

    def __init__(
        self,
        cfg: WindowConfig,
        stages: Sequence[str] = DEFAULT_STAGES,
        outputs: Sequence[str] = DEFAULT_OUTPUTS,
        input_key: str = "packets",
    ):
        self.cfg = cfg
        self.stages: tuple[Stage, ...] = tuple(
            self._resolve(name) for name in stages
        )
        self.outputs = tuple(outputs)
        self.input_key = input_key

        available = {input_key}
        for s in self.stages:
            missing = set(s.requires) - available
            if missing:
                raise ValueError(
                    f"stage {s.name!r} requires {sorted(missing)} which no "
                    f"earlier stage provides (have {sorted(available)})"
                )
            available |= set(s.provides)
        unmet = set(self.outputs) - available
        if unmet:
            raise ValueError(
                f"requested outputs {sorted(unmet)} are not provided by "
                f"stages {[s.name for s in self.stages]}"
            )
        self._jitted = jax.jit(self._forward)
        self._jit_cache: dict[tuple[bool, bool], Callable] = {
            (False, False): self._jitted
        }

    def jitted(self, donate: bool = False, batched: bool = False) -> Callable:
        """The compiled step function.

        ``donate=True`` compiles with ``donate_argnums=0``: XLA recycles
        the input batch buffer into the step's outputs, which is what keeps
        device memory O(in-flight window) under the async policies (many
        batches are submitted before the first is retired).  Donation is
        *safe* for every registered stage graph because stages are pure
        functions of the context dict — the caller must simply not reuse
        the batch array after the call, which the engine's loops never do.
        When no output can alias the input (e.g. a stats-only graph), XLA
        falls back to a copy and jax warns; the semantics are unchanged, so
        that warning is suppressed here.

        ``batched=True`` vmaps the forward over a leading chunk axis: one
        call takes ``[K, *batch_shape]`` and returns outputs with a leading
        ``K`` axis — the engine's batched multi-window submission
        (``submit_batches``) uses this to amortize K dispatches into one.
        Per-batch outputs are bit-identical to K separate calls (vmap of a
        pure function), which the equivalence suite asserts.
        """
        key = (donate, batched)
        if key not in self._jit_cache:
            fwd = jax.vmap(self._forward) if batched else self._forward
            jfn = jax.jit(fwd, donate_argnums=0 if donate else ())
            if donate:
                def step(batch, _jfn=jfn):
                    with warnings.catch_warnings():
                        warnings.filterwarnings(
                            "ignore",
                            message="Some donated buffers were not usable",
                        )
                        return _jfn(batch)
                self._jit_cache[key] = step
            else:
                self._jit_cache[key] = jfn
        return self._jit_cache[key]

    @staticmethod
    def _resolve(name: str) -> Stage:
        if isinstance(name, Stage):
            return name
        try:
            return STAGE_REGISTRY[name]
        except KeyError:
            raise ValueError(
                f"unknown stage {name!r}; registered: "
                f"{sorted(STAGE_REGISTRY)}"
            ) from None

    def _forward(self, batch: jax.Array) -> dict:
        ctx = {self.input_key: batch}
        for s in self.stages:
            ctx.update(s.fn(ctx, self.cfg))
        return {k: ctx[k] for k in self.outputs}

    def __call__(self, batch: jax.Array) -> dict:
        return self._jitted(batch)
