"""Declarative stage graph: anonymize -> build -> merge -> analytics.

A Stage is a named, pure function over a context dict of named arrays
(``packets``, ``windows``, ``matrix``, ...).  A StageGraph is an ordered
selection of stages, validated at construction (every stage's ``requires``
must be provided upstream, every requested output must exist) and compiled
to a single jitted device function ``[W, n, 2] packets -> outputs dict``.

This replaces the per-pipeline hand-wired ``process_batch`` closures: the
same graph runs under every execution policy, and new stages (e.g. a flow
aggregator, a second anonymization pass) register once and become available
to every source/sink/policy combination.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import analytics
from repro.core import anonymize as anon
from repro.core.build import build_window
from repro.core.window import WindowConfig, merge_tree


@dataclasses.dataclass(frozen=True)
class Stage:
    """One named pipeline step: ctx subset in -> new ctx entries out."""

    name: str
    requires: tuple[str, ...]
    provides: tuple[str, ...]
    fn: Callable[[dict, WindowConfig], dict]


STAGE_REGISTRY: dict[str, Stage] = {}


def register_stage(name: str, requires: Sequence[str],
                   provides: Sequence[str]):
    """Register a stage fn(ctx, cfg) -> dict of provided entries."""

    def deco(fn):
        STAGE_REGISTRY[name] = Stage(
            name=name, requires=tuple(requires), provides=tuple(provides),
            fn=fn,
        )
        return fn

    return deco


@register_stage("anonymize", requires=("packets",), provides=("packets",))
def _anonymize(ctx, cfg):
    # Both schemes are elementwise bijections over uint32, so they apply to
    # the whole [W, n, 2] batch at once (== vmap over windows).
    return {
        "packets": anon.anonymize_packets(
            ctx["packets"], cfg.anonymization_key, cfg.anonymization
        )
    }


@register_stage("build", requires=("packets",), provides=("windows",))
def _build(ctx, cfg):
    dtype = jnp.dtype(cfg.val_dtype)
    windows = jax.vmap(lambda p: build_window(p, dtype=dtype))(ctx["packets"])
    return {"windows": windows}


@register_stage("merge", requires=("windows",),
                provides=("matrix", "merge_overflow"))
def _merge(ctx, cfg):
    merged, overflow = merge_tree(ctx["windows"], cfg)
    return {"matrix": merged, "merge_overflow": overflow}


@register_stage("analytics", requires=("matrix",), provides=("stats",))
def _analytics(ctx, cfg):
    return {"stats": analytics.window_stats(ctx["matrix"])}


@register_stage("window_analytics", requires=("windows",),
                provides=("window_stats",))
def _window_analytics(ctx, cfg):
    return {"window_stats": analytics.window_stats_batched(ctx["windows"])}


DEFAULT_STAGES = ("anonymize", "build", "merge", "analytics")
DEFAULT_OUTPUTS = ("stats", "merge_overflow")


class StageGraph:
    """Validated, jitted composition of registered stages."""

    def __init__(
        self,
        cfg: WindowConfig,
        stages: Sequence[str] = DEFAULT_STAGES,
        outputs: Sequence[str] = DEFAULT_OUTPUTS,
    ):
        self.cfg = cfg
        self.stages: tuple[Stage, ...] = tuple(
            self._resolve(name) for name in stages
        )
        self.outputs = tuple(outputs)

        available = {"packets"}
        for s in self.stages:
            missing = set(s.requires) - available
            if missing:
                raise ValueError(
                    f"stage {s.name!r} requires {sorted(missing)} which no "
                    f"earlier stage provides (have {sorted(available)})"
                )
            available |= set(s.provides)
        unmet = set(self.outputs) - available
        if unmet:
            raise ValueError(
                f"requested outputs {sorted(unmet)} are not provided by "
                f"stages {[s.name for s in self.stages]}"
            )
        self._jitted = jax.jit(self._forward)

    @staticmethod
    def _resolve(name: str) -> Stage:
        if isinstance(name, Stage):
            return name
        try:
            return STAGE_REGISTRY[name]
        except KeyError:
            raise ValueError(
                f"unknown stage {name!r}; registered: "
                f"{sorted(STAGE_REGISTRY)}"
            ) from None

    def _forward(self, batch: jax.Array) -> dict:
        ctx = {"packets": batch}
        for s in self.stages:
            ctx.update(s.fn(ctx, self.cfg))
        return {k: ctx[k] for k in self.outputs}

    def __call__(self, batch: jax.Array) -> dict:
        return self._jitted(batch)
