"""repro.engine — the unified ingest subsystem.

Source -> Stage graph -> Sink, under a pluggable execution policy:

* Sources (``engine.source``): ``uniform``/``zipf`` synthetic traffic,
  pcap-lite replay, Suricata-style flow records (synthetic or EVE-JSON),
  or any iterable of window batches.
* Stages (``engine.stages``): declarative, validated, jitted
  anonymize -> build -> merge -> analytics graph, plus the value-carrying
  flow path (anonymize_flows -> build_flow -> merge_flow) and per-window
  ``fanout`` histograms.
* Sinks (``engine.sinks``): stats accumulation, top-k heavy hitters,
  matrix retention, streaming anomaly flagging (z-scored fan-out
  histograms), anonymized pcap-lite replay capture.
* Policies (``engine.policies``): ``blocking`` (GraphBLAS-only),
  ``double_buffered`` (GraphBLAS+IO), ``triple_buffered`` (3-deep queue),
  ``async_pipelined`` (async dispatch + donated buffers, ring of in-flight
  batches), ``sharded`` (mesh-parallel with the exact all_to_all row-block
  merge), ``sharded_pipelined`` (sharded + prefetch + async ring).
* Faults (``engine.faults``): deterministic fault injection
  (``FaultPlan``), bounded-retry + quarantine survival
  (``RetryingSource``, ``QuarantineSink``), and the per-run
  ``FaultTolerance`` config ``TrafficEngine.run`` consumes; paired with
  engine checkpoints (``checkpoint_every=``/``resume=``) for
  crash-consistent, bit-identical resume (DESIGN.md "Fault tolerance &
  resume").

The always-on service layer (``repro.serve``) wraps a ``TrafficEngine``
in a socket daemon: streaming ingest, roll-up retention, flagged-window
export, and a concurrent query API (DESIGN.md "Always-on service").

See DESIGN.md at the repo root for the architecture; ``core.stream`` and
``data.pipeline`` are compatibility shims over this package.
"""

from repro.engine.engine import TrafficEngine  # noqa: F401
from repro.engine.faults import (  # noqa: F401
    FaultCounters,
    FaultInjectingSink,
    FaultInjectingSource,
    FaultPlan,
    FaultSpec,
    FaultTolerance,
    PermanentSourceError,
    PoisonedBatchError,
    QuarantineSink,
    RetryingSource,
    SinkWriteError,
    SourceTimeoutError,
    TransientSourceError,
    make_batch_validator,
)
from repro.engine.policies import (  # noqa: F401
    AsyncPipelinedPolicy,
    BlockingPolicy,
    DoubleBufferedPolicy,
    ExecutionPolicy,
    ShardedPipelinedPolicy,
    ShardedPolicy,
    TripleBufferedPolicy,
    canonical_policies,
    make_policy,
)
from repro.engine.prefetch import (  # noqa: F401
    BoundedPrefetcher,
    WorkerDiedError,
    WorkerKilled,
)
from repro.engine.sinks import (  # noqa: F401
    AnomalySink,
    MatrixRetention,
    PcapLiteWriterSink,
    Sink,
    StatsAccumulator,
    TopKHeavyHitters,
)
from repro.engine.source import (  # noqa: F401
    DeviceSyntheticFlowSource,
    DeviceSyntheticSource,
    IterableSource,
    PcapLiteSource,
    SkippingSource,
    Source,
    SuricataFlowSource,
    SyntheticFlowSource,
    SyntheticSource,
    as_source,
    fast_forward,
)
from repro.engine.stages import (  # noqa: F401
    DEFAULT_STAGES,
    FLOW_STAGES,
    Stage,
    StageGraph,
    register_stage,
)
from repro.engine.telemetry import EngineReport, packets_in_item  # noqa: F401
