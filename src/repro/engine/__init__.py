"""repro.engine — the unified ingest subsystem.

Source -> Stage graph -> Sink, under a pluggable execution policy:

* Sources (``engine.source``): ``uniform``/``zipf`` synthetic traffic,
  pcap-lite replay, or any iterable of window batches.
* Stages (``engine.stages``): declarative, validated, jitted
  anonymize -> build -> merge -> analytics graph.
* Sinks (``engine.sinks``): stats accumulation, top-k heavy hitters,
  matrix retention.
* Policies (``engine.policies``): ``blocking`` (GraphBLAS-only),
  ``double_buffered`` (GraphBLAS+IO), ``sharded`` (mesh-parallel with the
  exact all_to_all row-block merge).

See DESIGN.md at the repo root for the architecture; ``core.stream`` and
``data.pipeline`` are compatibility shims over this package.
"""

from repro.engine.engine import TrafficEngine  # noqa: F401
from repro.engine.policies import (  # noqa: F401
    BlockingPolicy,
    DoubleBufferedPolicy,
    ExecutionPolicy,
    ShardedPolicy,
    make_policy,
)
from repro.engine.prefetch import BoundedPrefetcher  # noqa: F401
from repro.engine.sinks import (  # noqa: F401
    MatrixRetention,
    Sink,
    StatsAccumulator,
    TopKHeavyHitters,
)
from repro.engine.source import (  # noqa: F401
    IterableSource,
    PcapLiteSource,
    Source,
    SyntheticSource,
    as_source,
)
from repro.engine.stages import (  # noqa: F401
    DEFAULT_STAGES,
    Stage,
    StageGraph,
    register_stage,
)
from repro.engine.telemetry import EngineReport, packets_in_item  # noqa: F401
