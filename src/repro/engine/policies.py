"""Swappable execution policies: how the Source -> Stage -> Sink loop runs.

* ``blocking``        — GraphBLAS-only (paper Fig. 2, red curve): transfer
  and build strictly alternate; times pure build throughput.
* ``double_buffered`` — GraphBLAS+IO (blue curve): a producer thread
  device_puts the next batch behind a bounded queue while the device builds
  the current one.  Generalizes the old ``core.stream`` loop.
* ``async_pipelined`` — GraphBLAS+IO plus async dispatch: a ring of up to
  ``max_in_flight`` submitted batches; ``block_until_ready`` only runs when
  the ring is full or at drain, and the stage graph is jitted with
  ``donate_argnums`` so consumed input buffers recycle into outputs.
* ``sharded``         — mesh-parallel windows with the exact row-block
  all_to_all merge (``engine.sharded``); per-batch output is the exact
  global stats dict.
* ``sharded_pipelined`` — ``sharded`` composed with the bounded-queue
  producer and the async ring, so mesh-parallel windows also overlap IO
  with the device build.

Every policy shares a consumption loop and returns the same
``EngineReport``, so per-policy pkt/s numbers are directly comparable.
Policies are pure scheduling: per-batch stats and matrices are identical
across all of them, which ``tests/test_engine_properties.py`` derives from
``canonical_policies()`` — registering a policy here automatically puts it
under that invariant.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.engine.prefetch import BoundedPrefetcher
from repro.engine.sharded import make_exact_ingest_step
from repro.engine.stages import StageGraph
from repro.engine.telemetry import EngineReport, packets_in_item


def _run_loop(
    items: Iterable,
    process_fn: Callable,
    *,
    policy_name: str,
    device_put_inline: bool,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
    consume: Callable | None = None,
    produce_time: Callable[[], float] | None = None,
    keep_results: bool = True,
) -> EngineReport:
    """The one pipeline loop every policy shares.

    ``device_put_inline`` charges host->device transfer to this thread
    (blocking/sharded); otherwise the producer thread already paid it and
    ``produce_time()`` reports the bill.  ``keep_results=False`` drops each
    batch's outputs after the sinks consume them (long runs stay O(1) in
    memory; sinks bound their own retention).
    """
    results = []
    n_items = 0
    n_measured = 0
    n_packets = 0
    process_s = 0.0
    produce_inline = 0.0
    start = None

    for item in items:
        if device_put_inline:
            t0 = time.perf_counter()
            dev = jax.device_put(item)
            if n_items >= warmup_items:
                produce_inline += time.perf_counter() - t0
        else:
            dev = item
        if n_items == warmup_items:
            start = time.perf_counter()
        t0 = time.perf_counter()
        out = jax.block_until_ready(process_fn(dev))
        dt = time.perf_counter() - t0
        if n_items >= warmup_items:
            # warmup (jit compile / first transfer) is excluded from ALL
            # timing — elapsed, process AND produce — so the produce/
            # process split always describes the measured window only
            process_s += dt
            n_packets += packets_in_item(item, packets_per_item)
            if keep_results:
                results.append(out)
            if consume is not None:
                consume(n_measured, out)
            n_measured += 1
        n_items += 1

    elapsed = (time.perf_counter() - start) if start is not None else 0.0
    produce_s = produce_inline if produce_time is None else produce_time()
    return EngineReport(
        batches=max(n_items - warmup_items, 0),
        packets=n_packets,
        elapsed_s=elapsed,
        produce_s=produce_s,
        process_s=process_s,
        results=results,
        policy=policy_name,
    )


def _validate_in_flight(max_in_flight: int) -> int:
    if max_in_flight < 1:
        raise ValueError(
            f"max_in_flight must be >= 1, got {max_in_flight}"
        )
    return max_in_flight


def _validate_positive(value: int, name: str) -> int:
    if value < 1:
        raise ValueError(f"{name} must be >= 1, got {value}")
    return value


def _run_async_loop(
    items: Iterable,
    process_fn: Callable,
    *,
    policy_name: str,
    max_in_flight: int,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
    consume: Callable | None = None,
    produce_time: Callable[[], float] | None = None,
    keep_results: bool = True,
    sync_timing: bool = False,
    inflight: collections.deque | None = None,
    submit_batches: int = 1,
    batched_process_fn: Callable | None = None,
) -> EngineReport:
    """Async-dispatch variant of the pipeline loop: submit without blocking,
    retire FIFO.

    Up to ``max_in_flight`` submitted dispatches await device completion at
    once; the oldest is retired (``block_until_ready`` -> results -> sinks)
    before a new one is submitted when the ring is full, and everything
    drains at end of stream.  Sinks therefore always observe results in
    submission order.  Warmup batches retire immediately so compile time
    never leaks into the measured window.

    ``submit_batches=K > 1`` turns on batched multi-window submission:
    source batches are stacked K at a time and dispatched through ONE
    ``batched_process_fn`` call (a vmapped stage graph), amortizing K
    dispatch/handoff rounds into one.  Retirement un-stacks the chunk and
    delivers each batch's outputs separately, still in submission order, so
    sinks and results are indistinguishable from K=1.  A final partial
    chunk is padded by repeating its last batch (one compiled shape, no
    recompile) and the padded lanes are dropped before delivery.

    Timing semantics (DESIGN.md "Async dispatch & donation"): ``process_s``
    is the *exposed* wait — wall-clock spent blocked on results, including
    the final drain; ``overlap_s`` is head-of-line in-flight time hidden
    behind host work, accounted over disjoint wall-clock segments so that
    ``process_s + overlap_s <= elapsed_s`` by construction.
    ``sync_timing=True`` retires every dispatch right after submission,
    restoring the per-dispatch blocking measurement (Fig. 2 comparability)
    at the cost of the overlap.

    A mid-stream failure (source, transform, or dispatch) quiesces every
    already-submitted dispatch before re-raising, so no in-flight device
    work outlives the loop; ``inflight`` may be passed in by the policy so
    its post-mortem emptiness is observable.
    """
    _validate_in_flight(max_in_flight)
    _validate_positive(submit_batches, "submit_batches")
    if submit_batches > 1 and batched_process_fn is None:
        raise ValueError("submit_batches > 1 needs a batched_process_fn")
    if inflight is None:
        inflight = collections.deque()
    results: list = []
    n_items = 0
    n_measured = 0  # measured batches submitted
    n_packets = 0
    wait_s = 0.0
    overlap_s = 0.0
    max_depth = 0
    start = None
    last_retire_end = None

    def retire_one():
        nonlocal wait_s, overlap_s, last_retire_end
        start_idx, n_real, submit_t, out = inflight.popleft()
        t0 = time.perf_counter()
        out = jax.block_until_ready(out)
        t1 = time.perf_counter()
        # head-of-line overlap: time this dispatch was in flight before we
        # blocked on it, clipped to start after the previous retirement so
        # segments never double count
        lo = submit_t if last_retire_end is None else max(submit_t,
                                                          last_retire_end)
        overlap_s += max(t0 - lo, 0.0)
        wait_s += t1 - t0
        last_retire_end = t1
        for j in range(n_real):
            # un-stack a K-chunk into its per-batch outputs (padded lanes
            # beyond n_real are simply never delivered)
            out_j = (out if submit_batches == 1
                     else jax.tree_util.tree_map(lambda v: v[j], out))
            if keep_results:
                results.append(out_j)
            if consume is not None:
                consume(start_idx + j, out_j)

    def submit(chunk):
        nonlocal n_measured, n_packets, max_depth
        while len(inflight) >= max_in_flight:
            retire_one()
        # count packets before dispatch: donation may invalidate the
        # buffers the moment they are submitted
        for d in chunk:
            n_packets += packets_in_item(d, packets_per_item)
        n_real = len(chunk)
        if submit_batches == 1:
            payload, fn = chunk[0], process_fn
        else:
            if n_real < submit_batches:
                chunk = chunk + [chunk[-1]] * (submit_batches - n_real)
            payload, fn = jnp.stack(chunk), batched_process_fn
        submit_t = time.perf_counter()
        out = fn(payload)  # async dispatch: no block here
        inflight.append((n_measured, n_real, submit_t, out))
        max_depth = max(max_depth, len(inflight))
        n_measured += n_real
        if sync_timing:
            retire_one()

    chunk: list = []
    try:
        for dev in items:  # the producer thread already device_put them
            if n_items == warmup_items:
                start = time.perf_counter()
            if n_items < warmup_items:
                # warmup (jit compile): retire immediately, deliver
                # nowhere; with K > 1 warm the K-stacked shape, which is
                # the only shape the measured loop will compile
                if submit_batches == 1:
                    jax.block_until_ready(process_fn(dev))
                else:
                    jax.block_until_ready(batched_process_fn(
                        jnp.stack([dev] * submit_batches)
                    ))
            else:
                chunk.append(dev)
                if len(chunk) == submit_batches:
                    submit(chunk)
                    chunk = []
            n_items += 1
        if chunk:
            submit(chunk)  # final partial chunk (padded when K > 1)
        while inflight:
            retire_one()
    except BaseException:
        # never leak in-flight device work past a failure: quiesce every
        # submitted dispatch (results are discarded), then re-raise
        while inflight:
            *_, out = inflight.popleft()
            try:
                jax.block_until_ready(out)
            # the original failure (re-raised below) is the story; a dead
            # in-flight batch failing its drain adds nothing to record
            except Exception:  # repro-lint: disable=swallowed-exception
                pass
        raise

    elapsed = (time.perf_counter() - start) if start is not None else 0.0
    return EngineReport(
        batches=n_measured,
        packets=n_packets,
        elapsed_s=elapsed,
        produce_s=0.0 if produce_time is None else produce_time(),
        process_s=wait_s,
        results=results,
        policy=policy_name,
        overlap_s=overlap_s,
        max_in_flight=max(max_depth, 1),
        submit_batches=submit_batches,
    )


class ExecutionPolicy:
    """How batches flow from a source through a process fn."""

    name = "base"

    def build_process_fn(self, graph: StageGraph | None, cfg,
                         workload: str = "packets") -> Callable:
        """Device function for this policy; default is the stage graph
        (which already encodes the workload — ``workload`` only matters to
        policies that build their own fused step, i.e. ``sharded``)."""
        if graph is None:
            raise ValueError(f"policy {self.name!r} needs a stage graph")
        return graph

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        raise NotImplementedError


class BlockingPolicy(ExecutionPolicy):
    """Strictly serial transfer + process (GraphBLAS-only timing)."""

    name = "blocking"

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        return _run_loop(
            iter(source), process_fn,
            policy_name=self.name, device_put_inline=True,
            packets_per_item=packets_per_item, warmup_items=warmup_items,
            consume=consume, keep_results=keep_results,
        )


class DoubleBufferedPolicy(ExecutionPolicy):
    """Producer thread(s) transfer behind a bounded queue (GraphBLAS+IO).

    ``producer_workers > 1`` runs N prefetch workers: source pulls stay
    serialized (so the stream is unchanged), but per-item transforms —
    ``device_put``, and for file sources the decode — run concurrently,
    with delivery re-sequenced into source order (see
    ``BoundedPrefetcher``).  Scheduling only: per-batch outputs are
    bit-identical at any worker count.
    """

    name = "double_buffered"

    def __init__(self, queue_depth: int = 2, producer_workers: int = 1):
        self.queue_depth = queue_depth
        self.producer_workers = _validate_positive(producer_workers,
                                                   "producer_workers")

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        # kept on the instance so a failed run's produce accounting stays
        # observable post-mortem (the prefetcher snapshots produce_s under
        # its lock, in-flight transforms included)
        pf = self._prefetcher = BoundedPrefetcher(
            iter(source), depth=self.queue_depth,
            transform=jax.device_put, untimed_items=warmup_items,
            workers=self.producer_workers,
        )
        try:
            report = _run_loop(
                pf, process_fn,
                policy_name=self.name, device_put_inline=False,
                packets_per_item=packets_per_item, warmup_items=warmup_items,
                consume=consume, produce_time=pf.produce_time,
                keep_results=keep_results,
            )
            report.producer_workers = self.producer_workers
            return report
        finally:
            pf.close()  # a failed run must not leak the producer thread


class TripleBufferedPolicy(DoubleBufferedPolicy):
    """``double_buffered`` with a 3-deep queue: the host generator may run a
    full batch ahead, absorbing produce-time jitter once host generation —
    not the device — is the bottleneck (the ROADMAP's triple-buffering
    preset).  Scheduling only: stats are bit-identical to every other
    policy, which the equivalence suite asserts."""

    name = "triple_buffered"

    def __init__(self, queue_depth: int = 3, producer_workers: int = 1):
        super().__init__(queue_depth=queue_depth,
                         producer_workers=producer_workers)


class _AsyncRingRunMixin:
    """The shared run() of the async policies: bounded-queue producer
    worker(s) feeding ``_run_async_loop``.  Hosts must set ``queue_depth``,
    ``max_in_flight``, ``sync_timing``, ``producer_workers``,
    ``submit_batches``, ``_batched_fn``, and ``_inflight``."""

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        # kept on the instance so a failed run's produce accounting stays
        # observable post-mortem (the prefetcher snapshots produce_s under
        # its lock, in-flight transforms included)
        pf = self._prefetcher = BoundedPrefetcher(
            iter(source), depth=self.queue_depth,
            transform=jax.device_put, untimed_items=warmup_items,
            workers=self.producer_workers,
        )
        bfn = None
        if self.submit_batches > 1:
            # engine runs set _batched_fn in build_process_fn (the vmapped
            # stage graph / sharded step); direct run() callers with a
            # custom process fn get a generic vmapped wrapper
            bfn = self._batched_fn
            if bfn is None:
                bfn = jax.jit(jax.vmap(process_fn))
        # a FRESH ring per run — concurrent runs on one policy instance
        # must not share in-flight state; the attribute only points at the
        # latest run's ring for post-mortem emptiness checks
        ring = self._inflight = collections.deque()
        try:
            report = _run_async_loop(
                pf, process_fn,
                policy_name=self.name, max_in_flight=self.max_in_flight,
                packets_per_item=packets_per_item,
                warmup_items=warmup_items, consume=consume,
                produce_time=pf.produce_time,
                keep_results=keep_results, sync_timing=self.sync_timing,
                inflight=ring, submit_batches=self.submit_batches,
                batched_process_fn=bfn,
            )
            report.producer_workers = self.producer_workers
            return report
        finally:
            pf.close()  # a failed run must not leak the producer thread


class AsyncPipelinedPolicy(_AsyncRingRunMixin, ExecutionPolicy):
    """``double_buffered`` plus async dispatch: a ring of in-flight batches.

    The producer thread still device_puts behind a bounded queue; on top of
    that, submissions exploit jax async dispatch — ``process_fn(dev)``
    returns before the device finishes, and the loop only calls
    ``block_until_ready`` when ``max_in_flight`` batches are outstanding or
    at end-of-stream drain.  Device->host readback of batch *i* therefore
    overlaps the build of batches *i+1 .. i+K-1*, which is where the
    paper's pipeline rate comes from.

    The stage graph is jitted with ``donate_argnums`` (``donate=True``) so
    each consumed input buffer is recycled into its batch's outputs and
    device memory stays O(max_in_flight), not O(stream).

    Scheduling only: per-batch stats/matrices are bit-identical to
    ``blocking`` (the equivalence suite enforces this), and sinks consume
    results in submission order.  ``sync_timing=True`` is the Fig.-2
    escape hatch: it restores per-batch blocking measurement so
    ``process_s`` means the same thing as under the synchronous policies.
    """

    name = "async_pipelined"

    def __init__(self, max_in_flight: int = 3, queue_depth: int = 2,
                 *, donate: bool = True, sync_timing: bool = False,
                 producer_workers: int = 1, submit_batches: int = 1):
        self.max_in_flight = _validate_in_flight(max_in_flight)
        self.queue_depth = queue_depth
        self.donate = donate
        self.sync_timing = sync_timing
        self.producer_workers = _validate_positive(producer_workers,
                                                   "producer_workers")
        self.submit_batches = _validate_positive(submit_batches,
                                                 "submit_batches")
        self._batched_fn: Callable | None = None
        # exposed so overlap tests (and post-mortems) can assert no batch
        # is ever left in flight
        self._inflight: collections.deque = collections.deque()

    def build_process_fn(self, graph: StageGraph | None, cfg,
                         workload: str = "packets") -> Callable:
        if graph is None:
            raise ValueError(f"policy {self.name!r} needs a stage graph")
        # the K-chunk variant rides the same graph: one donated, vmapped
        # call takes [K, *batch] and the loop un-stacks per-batch outputs
        self._batched_fn = (graph.jitted(donate=self.donate, batched=True)
                            if self.submit_batches > 1 else None)
        return graph.jitted(donate=self.donate)


class ShardedPolicy(ExecutionPolicy):
    """Mesh-parallel windows + exact all_to_all row-block merge.

    Ignores the stage graph's stage selection: the shard_map step fuses
    anonymize/build/merge/analytics per shard, and its per-batch output is
    the exact global stats subset (so sinks requiring ``matrix`` are
    rejected by the engine for this policy).
    """

    name = "sharded"

    def __init__(self, mesh=None, *, route_capacity_factor: float = 2.0):
        self.mesh = mesh
        self.route_capacity_factor = route_capacity_factor

    def build_process_fn(self, graph, cfg,
                         workload: str = "packets") -> Callable:
        mesh = self.mesh
        if mesh is None:
            from repro.launch.mesh import make_local_mesh

            mesh = self.mesh = make_local_mesh()
        step = jax.jit(make_exact_ingest_step(
            mesh, cfg, route_capacity_factor=self.route_capacity_factor,
            workload=workload,
        ))
        n_dev = mesh.size

        def process(batch):
            if batch.shape[0] % n_dev:
                raise ValueError(
                    f"windows_per_batch={batch.shape[0]} must divide by "
                    f"mesh size {n_dev} for the sharded policy"
                )
            out = step(batch)
            return {"stats": out, "merge_overflow": out["merge_overflow"]}

        return process

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        return _run_loop(
            iter(source), process_fn,
            policy_name=self.name, device_put_inline=True,
            packets_per_item=packets_per_item, warmup_items=warmup_items,
            consume=consume, keep_results=keep_results,
        )


class ShardedPipelinedPolicy(_AsyncRingRunMixin, ShardedPolicy):
    """``sharded`` composed with the bounded-queue producer + async ring.

    The plain ``sharded`` policy device_puts each batch inline, serializing
    host transfer against the mesh step; here a ``BoundedPrefetcher``
    thread pays the transfer while the mesh builds the previous batch, and
    up to ``max_in_flight`` shard_map steps are dispatched before the loop
    blocks (the multi-batch sharded pipelining from the ROADMAP).  Output
    contract is inherited unchanged: the exact global stats subset, so
    stats are identical to ``sharded``/``blocking`` per batch.
    """

    name = "sharded_pipelined"

    def __init__(self, mesh=None, *, route_capacity_factor: float = 2.0,
                 queue_depth: int = 2, max_in_flight: int = 2,
                 sync_timing: bool = False, producer_workers: int = 1,
                 submit_batches: int = 1):
        super().__init__(mesh, route_capacity_factor=route_capacity_factor)
        self.max_in_flight = _validate_in_flight(max_in_flight)
        self.queue_depth = queue_depth
        self.sync_timing = sync_timing
        self.producer_workers = _validate_positive(producer_workers,
                                                   "producer_workers")
        self.submit_batches = _validate_positive(submit_batches,
                                                 "submit_batches")
        self._batched_fn: Callable | None = None
        self._inflight: collections.deque = collections.deque()

    def build_process_fn(self, graph, cfg,
                         workload: str = "packets") -> Callable:
        process = super().build_process_fn(graph, cfg, workload=workload)
        # vmap over the shard_map step: one [K, W, ...] dispatch runs K
        # sharded builds+merges; slices are bit-identical to K single calls
        self._batched_fn = (jax.jit(jax.vmap(process))
                            if self.submit_batches > 1 else None)
        return process


_POLICIES = {
    "blocking": BlockingPolicy,
    "double_buffered": DoubleBufferedPolicy,
    "stream": DoubleBufferedPolicy,  # the paper's name for it
    "triple_buffered": TripleBufferedPolicy,
    "async_pipelined": AsyncPipelinedPolicy,
    "sharded": ShardedPolicy,
    "distributed": ShardedPolicy,  # launcher-CLI name
    "sharded_pipelined": ShardedPipelinedPolicy,
}


def canonical_policies() -> dict[str, type]:
    """Registered policies minus aliases (an alias is a registry name its
    class does not claim as ``cls.name``, e.g. ``stream``/``distributed``).

    The policy-equivalence suite derives its test matrix from this, so a
    policy registered in ``_POLICIES`` is subject to the stats/matrix
    identity invariant *by construction* — there is no second list to
    forget to update.
    """
    return {name: cls for name, cls in _POLICIES.items()
            if cls.name == name}


def make_policy(spec, **knobs) -> ExecutionPolicy:
    """Resolve a policy spec: instance passes through, string looks up.

    Keyword knobs (``producer_workers=``, ``submit_batches=``,
    ``queue_depth=``, ``max_in_flight=``, ...) forward to the policy
    constructor; ``None`` values are dropped so CLI plumbing can pass
    unset flags through.  A knob the policy's constructor does not take is
    an error naming the supported set — silently ignoring e.g.
    ``submit_batches`` on ``blocking`` would misreport what a benchmark
    measured.
    """
    if isinstance(spec, ExecutionPolicy):
        if any(v is not None for v in knobs.values()):
            raise ValueError(
                "policy knobs cannot be applied to an already-constructed "
                f"policy instance ({spec.name!r}); construct it with them"
            )
        return spec
    try:
        cls = _POLICIES[spec]
    except KeyError:
        raise ValueError(
            f"unknown policy {spec!r}; choose from {sorted(_POLICIES)}"
        ) from None
    knobs = {k: v for k, v in knobs.items() if v is not None}
    try:
        return cls(**knobs)
    except TypeError:
        import inspect

        allowed = sorted(set(inspect.signature(cls.__init__).parameters)
                         - {"self"})
        raise ValueError(
            f"policy {spec!r} does not accept {sorted(knobs)}; "
            f"supported knobs: {allowed}"
        ) from None
