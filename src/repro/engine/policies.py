"""Swappable execution policies: how the Source -> Stage -> Sink loop runs.

* ``blocking``        — GraphBLAS-only (paper Fig. 2, red curve): transfer
  and build strictly alternate; times pure build throughput.
* ``double_buffered`` — GraphBLAS+IO (blue curve): a producer thread
  device_puts the next batch behind a bounded queue while the device builds
  the current one.  Generalizes the old ``core.stream`` loop.
* ``sharded``         — mesh-parallel windows with the exact row-block
  all_to_all merge (``engine.sharded``); per-batch output is the exact
  global stats dict.

All three share one consumption loop and return the same ``EngineReport``,
so per-policy pkt/s numbers are directly comparable.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable

import jax

from repro.engine.prefetch import BoundedPrefetcher
from repro.engine.sharded import make_exact_ingest_step
from repro.engine.stages import StageGraph
from repro.engine.telemetry import EngineReport, packets_in_item


def _run_loop(
    items: Iterable,
    process_fn: Callable,
    *,
    policy_name: str,
    device_put_inline: bool,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
    consume: Callable | None = None,
    produce_time: Callable[[], float] | None = None,
    keep_results: bool = True,
) -> EngineReport:
    """The one pipeline loop every policy shares.

    ``device_put_inline`` charges host->device transfer to this thread
    (blocking/sharded); otherwise the producer thread already paid it and
    ``produce_time()`` reports the bill.  ``keep_results=False`` drops each
    batch's outputs after the sinks consume them (long runs stay O(1) in
    memory; sinks bound their own retention).
    """
    results = []
    n_items = 0
    n_measured = 0
    n_packets = 0
    process_s = 0.0
    produce_inline = 0.0
    start = None

    for item in items:
        if device_put_inline:
            t0 = time.perf_counter()
            dev = jax.device_put(item)
            produce_inline += time.perf_counter() - t0
        else:
            dev = item
        if n_items == warmup_items:
            start = time.perf_counter()
        t0 = time.perf_counter()
        out = jax.block_until_ready(process_fn(dev))
        process_s += time.perf_counter() - t0
        if n_items >= warmup_items:
            n_packets += packets_in_item(item, packets_per_item)
            if keep_results:
                results.append(out)
            if consume is not None:
                consume(n_measured, out)
            n_measured += 1
        n_items += 1

    elapsed = (time.perf_counter() - start) if start is not None else 0.0
    produce_s = produce_inline if produce_time is None else produce_time()
    return EngineReport(
        batches=max(n_items - warmup_items, 0),
        packets=n_packets,
        elapsed_s=elapsed,
        produce_s=produce_s,
        process_s=process_s,
        results=results,
        policy=policy_name,
    )


class ExecutionPolicy:
    """How batches flow from a source through a process fn."""

    name = "base"

    def build_process_fn(self, graph: StageGraph | None, cfg,
                         workload: str = "packets") -> Callable:
        """Device function for this policy; default is the stage graph
        (which already encodes the workload — ``workload`` only matters to
        policies that build their own fused step, i.e. ``sharded``)."""
        if graph is None:
            raise ValueError(f"policy {self.name!r} needs a stage graph")
        return graph

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        raise NotImplementedError


class BlockingPolicy(ExecutionPolicy):
    """Strictly serial transfer + process (GraphBLAS-only timing)."""

    name = "blocking"

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        return _run_loop(
            iter(source), process_fn,
            policy_name=self.name, device_put_inline=True,
            packets_per_item=packets_per_item, warmup_items=warmup_items,
            consume=consume, keep_results=keep_results,
        )


class DoubleBufferedPolicy(ExecutionPolicy):
    """Producer thread transfers behind a bounded queue (GraphBLAS+IO)."""

    name = "double_buffered"

    def __init__(self, queue_depth: int = 2):
        self.queue_depth = queue_depth

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        pf = BoundedPrefetcher(
            iter(source), depth=self.queue_depth, transform=jax.device_put
        )
        return _run_loop(
            pf, process_fn,
            policy_name=self.name, device_put_inline=False,
            packets_per_item=packets_per_item, warmup_items=warmup_items,
            consume=consume, produce_time=lambda: pf.produce_s,
            keep_results=keep_results,
        )


class TripleBufferedPolicy(DoubleBufferedPolicy):
    """``double_buffered`` with a 3-deep queue: the host generator may run a
    full batch ahead, absorbing produce-time jitter once host generation —
    not the device — is the bottleneck (the ROADMAP's triple-buffering
    preset).  Scheduling only: stats are bit-identical to every other
    policy, which the equivalence suite asserts."""

    name = "triple_buffered"

    def __init__(self, queue_depth: int = 3):
        super().__init__(queue_depth=queue_depth)


class ShardedPolicy(ExecutionPolicy):
    """Mesh-parallel windows + exact all_to_all row-block merge.

    Ignores the stage graph's stage selection: the shard_map step fuses
    anonymize/build/merge/analytics per shard, and its per-batch output is
    the exact global stats subset (so sinks requiring ``matrix`` are
    rejected by the engine for this policy).
    """

    name = "sharded"

    def __init__(self, mesh=None, *, route_capacity_factor: float = 2.0):
        self.mesh = mesh
        self.route_capacity_factor = route_capacity_factor

    def build_process_fn(self, graph, cfg,
                         workload: str = "packets") -> Callable:
        mesh = self.mesh
        if mesh is None:
            from repro.launch.mesh import make_local_mesh

            mesh = self.mesh = make_local_mesh()
        step = jax.jit(make_exact_ingest_step(
            mesh, cfg, route_capacity_factor=self.route_capacity_factor,
            workload=workload,
        ))
        n_dev = mesh.size

        def process(batch):
            if batch.shape[0] % n_dev:
                raise ValueError(
                    f"windows_per_batch={batch.shape[0]} must divide by "
                    f"mesh size {n_dev} for the sharded policy"
                )
            out = step(batch)
            return {"stats": out, "merge_overflow": out["merge_overflow"]}

        return process

    def run(self, source, process_fn, *, packets_per_item=None,
            warmup_items=0, consume=None,
            keep_results=True) -> EngineReport:
        return _run_loop(
            iter(source), process_fn,
            policy_name=self.name, device_put_inline=True,
            packets_per_item=packets_per_item, warmup_items=warmup_items,
            consume=consume, keep_results=keep_results,
        )


_POLICIES = {
    "blocking": BlockingPolicy,
    "double_buffered": DoubleBufferedPolicy,
    "stream": DoubleBufferedPolicy,  # the paper's name for it
    "triple_buffered": TripleBufferedPolicy,
    "sharded": ShardedPolicy,
    "distributed": ShardedPolicy,  # launcher-CLI name
}


def make_policy(spec) -> ExecutionPolicy:
    """Resolve a policy spec: instance passes through, string looks up."""
    if isinstance(spec, ExecutionPolicy):
        return spec
    try:
        return _POLICIES[spec]()
    except KeyError:
        raise ValueError(
            f"unknown policy {spec!r}; choose from {sorted(_POLICIES)}"
        ) from None
