"""Checked-in baseline of grandfathered findings.

The baseline is the escape hatch for findings that are known, justified,
and tracked: a JSON file mapping each entry to the finding key
``(path, line, rule)`` plus a mandatory ``justification``.  The CLI only
fails on findings *not* in the baseline, and reports stale entries (in the
baseline but no longer found) so the file can never rot — a fresh scan and
the checked-in file must agree exactly, which ``tests/test_analysis.py``
pins.

The repo's own baseline lives at ``analysis-baseline.json`` in the repo
root and is empty: every violation the pass surfaced was fixed, not
grandfathered.  The machinery stays because the next rule added will need
a migration path.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable

from repro.analysis.framework import Finding

BASELINE_NAME = "analysis-baseline.json"


def load_baseline(path: str | Path) -> dict[tuple[str, int, str], str]:
    """-> {(path, line, rule): justification}; missing file = empty."""
    path = Path(path)
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    entries = {}
    for e in data.get("findings", []):
        just = e.get("justification", "")
        if not just:
            raise ValueError(
                f"baseline entry {e.get('path')}:{e.get('line')} "
                f"[{e.get('rule')}] has no justification — baselined "
                f"findings must say why they are allowed to stand"
            )
        entries[(e["path"], int(e["line"]), e["rule"])] = just
    return entries


def write_baseline(path: str | Path, findings: Iterable[Finding],
                   justification: str = "grandfathered by --write-baseline"
                   ) -> None:
    path = Path(path)
    payload = {
        "comment": (
            "Grandfathered repro.analysis findings. Every entry needs a "
            "justification; prefer fixing over baselining. The suite "
            "asserts this file matches a fresh scan (no stale entries)."
        ),
        "findings": [
            {"path": f.path, "line": f.line, "rule": f.rule,
             "message": f.message, "justification": justification}
            for f in sorted(findings)
        ],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def split_by_baseline(findings: Iterable[Finding],
                      baseline: dict[tuple[str, int, str], str]):
    """-> (new_findings, baselined_findings, stale_keys)."""
    findings = list(findings)
    keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in baseline]
    old = [f for f in findings if f.key() in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, old, stale
