"""The repo-specific rule catalogue.

Each rule machine-checks one contract the engine's correctness rests on
(DESIGN.md "Static analysis & invariants" documents the why per rule):

* ``use-after-donate``    — donated buffers are unobservable after dispatch
* ``tracer-leak``         — no host side effects inside traced functions
* ``raw-shard-map``       — shard_map only via ``distributed.sharding``
* ``raw-mesh``            — mesh construction only via ``launch.mesh``
* ``dtype-discipline``    — packed-key integer math keeps explicit widths
* ``thread-shared-state`` — worker threads mutate shared attrs under a lock

Rules are best-effort AST analyses, not type checkers: they trade soundness
for zero-dependency speed and zero false-negative cost on the patterns this
repo actually writes.  Anything a rule cannot see (donation through a
function parameter, dynamic stage registration) is covered by the runtime
sanitizers and the equivalence suite instead.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.framework import Finding, Rule, register_rule


# ---------------------------------------------------------------------------
# shared AST utilities
# ---------------------------------------------------------------------------
class ImportMap(ast.NodeVisitor):
    """Local alias -> fully dotted origin ("np" -> "numpy", "Mesh" ->
    "jax.sharding.Mesh", "smap" -> "jax.experimental.shard_map.shard_map").
    """

    def __init__(self):
        self.aliases: dict[str, str] = {}

    @classmethod
    def of(cls, tree: ast.AST) -> "ImportMap":
        m = cls()
        m.visit(tree)
        return m

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            if a.asname:
                self.aliases[a.asname] = a.name
            else:
                root = a.name.split(".")[0]
                self.aliases[root] = root

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module is None or node.level:  # relative imports: unused here
            return
        for a in node.names:
            self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted origin of a Name/Attribute chain, or None."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        return ".".join([root, *reversed(parts)])


def iter_scopes(tree: ast.AST) -> Iterator[tuple[ast.AST, list[ast.stmt]]]:
    """(scope node, its body) for the module and every function."""
    yield tree, list(tree.body)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, list(node.body)


def walk_shallow(stmts: list[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function/class scopes
    (those are separate scopes with their own bindings)."""
    stack: list[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue  # yielded as a statement, but its body is not ours
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# use-after-donate
# ---------------------------------------------------------------------------
def _is_donating_factory(call: ast.Call) -> bool:
    """A call whose *result* donates its inputs: any ``donate=...`` (not
    literally False) or ``donate_argnums``/``donate_argnames`` keyword —
    ``graph.jitted(donate=True)``, ``jax.jit(f, donate_argnums=0)``."""
    for kw in call.keywords:
        if kw.arg == "donate":
            return not (isinstance(kw.value, ast.Constant)
                        and kw.value.value is False)
        if kw.arg in ("donate_argnums", "donate_argnames"):
            return not (isinstance(kw.value, ast.Constant)
                        and kw.value.value is None)
    return False


def _linear_statements(body: list[ast.stmt]) -> list[ast.stmt]:
    """Statements in textual order, compound bodies flattened, nested
    function/class scopes excluded (they are analyzed separately)."""
    out: list[ast.stmt] = []
    for stmt in body:
        out.append(stmt)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                out.extend(_linear_statements(sub))
        for handler in getattr(stmt, "handlers", ()):
            out.extend(_linear_statements(handler.body))
    return out


def _header_walk(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Walk only the expressions evaluated *by this statement itself* —
    for compound statements, the header (loop iter, if/while test, with
    items), not the nested body statements already linearized."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        parts: list[ast.AST] = [stmt.target, stmt.iter]
    elif isinstance(stmt, (ast.While, ast.If)):
        parts = [stmt.test]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        parts = [i.context_expr for i in stmt.items]
        parts += [i.optional_vars for i in stmt.items if i.optional_vars]
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
        parts = [*stmt.decorator_list, *stmt.args.defaults,
                 *[d for d in stmt.args.kw_defaults if d is not None]]
    elif isinstance(stmt, ast.ClassDef):
        parts = [*stmt.decorator_list, *stmt.bases]
    elif isinstance(stmt, ast.Try):
        parts = []
    else:
        parts = [stmt]
    for p in parts:
        yield from ast.walk(p)


@register_rule
class UseAfterDonateRule(Rule):
    id = "use-after-donate"
    doc = (
        "A variable passed to a donate=True / donate_argnums jitted "
        "callable is read again in the same scope. Donated buffers are "
        "recycled into the step's outputs the moment the call is "
        "dispatched — a later read sees a deleted array (async policies) "
        "or silently stale memory. Rebinding the name in the same "
        "statement (`state, m = step(state, x)`) and `.is_deleted()` "
        "probes are the sanctioned patterns and are not flagged."
    )

    def check(self, tree, source, path):
        findings: list[Finding] = []
        for _scope, body in iter_scopes(tree):
            findings.extend(self._check_scope(body, path))
        return findings

    def _check_scope(self, body, path) -> list[Finding]:
        stmts = _linear_statements(body)

        # names bound to donating callables anywhere in this scope
        donating_names: set[str] = set()
        for stmt in stmts:
            if (isinstance(stmt, ast.Assign)
                    and isinstance(stmt.value, ast.Call)
                    and _is_donating_factory(stmt.value)):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        donating_names.add(t.id)

        # per-statement name usage: loads (first node kept for the line),
        # stores, and donation events
        loads: list[dict[str, ast.Name]] = []
        stores: list[set[str]] = []
        events: list[tuple[int, str, int]] = []  # (stmt idx, name, line)
        for i, stmt in enumerate(stmts):
            ld: dict[str, ast.Name] = {}
            st: set[str] = set()
            deleted_probes: set[int] = set()
            nodes = list(_header_walk(stmt))
            for n in nodes:
                # dev.is_deleted() is how code *checks* donation happened
                if (isinstance(n, ast.Attribute) and n.attr == "is_deleted"
                        and isinstance(n.value, ast.Name)):
                    deleted_probes.add(id(n.value))
            for n in nodes:
                if not isinstance(n, ast.Name):
                    continue
                if isinstance(n.ctx, ast.Load):
                    if id(n) not in deleted_probes:
                        ld.setdefault(n.id, n)
                else:
                    st.add(n.id)
            loads.append(ld)
            stores.append(st)
            for n in nodes:
                if not isinstance(n, ast.Call):
                    continue
                f = n.func
                donating = (
                    (isinstance(f, ast.Name) and f.id in donating_names)
                    or (isinstance(f, ast.Call)
                        and _is_donating_factory(f))
                )
                if not donating:
                    continue
                for arg in n.args:
                    # a same-statement rebind (state, m = step(state, x))
                    # replaces the donated buffer: the canonical pattern
                    if isinstance(arg, ast.Name) and arg.id not in st:
                        events.append((i, arg.id, n.lineno))

        findings = []
        for i, name, call_line in events:
            for j in range(i + 1, len(stmts)):
                if name in loads[j]:
                    findings.append(self.finding(
                        path, loads[j][name],
                        f"'{name}' is read after being passed to a "
                        f"donating call on line {call_line}; donated "
                        f"buffers are unobservable after dispatch",
                    ))
                    break
                if name in stores[j]:
                    break
        return findings


# ---------------------------------------------------------------------------
# tracer-leak
# ---------------------------------------------------------------------------
_TRACING_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.pjit",
    "jax.experimental.pjit.pjit",
    "jax.experimental.pallas.pallas_call",
}
_PARTIAL = {"functools.partial", "partial"}
_TIME_CALLS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.process_time",
}
_NUMPY_OK = {"numpy.dtype", "numpy.iinfo", "numpy.finfo"}
_MUTATORS = {"append", "extend", "insert", "add", "update", "setdefault",
             "pop", "popleft", "appendleft", "clear"}


@register_rule
class TracerLeakRule(Rule):
    id = "tracer-leak"
    doc = (
        "Python side effects inside a traced function (jax.jit / vmap / "
        "pallas_call wrappers, @register_stage stage bodies): print, wall "
        "clocks, numpy host ops on traced values, global/nonlocal, or "
        "mutation of state defined outside the function. These run once "
        "at trace time, not per step — silent wrong-answer territory."
    )

    def check(self, tree, source, path):
        imap = ImportMap.of(tree)
        traced = self._traced_functions(tree, imap)
        findings: list[Finding] = []
        for fn in traced:
            findings.extend(self._check_traced(fn, imap, path))
        return findings

    def _is_tracing_wrapper(self, node, imap) -> bool:
        res = imap.resolve(node)
        if res in _TRACING_WRAPPERS:
            return True
        # partial(jax.jit, ...) / partial(pl.pallas_call, ...)
        if isinstance(node, ast.Call) and imap.resolve(node.func) in _PARTIAL:
            return bool(node.args) and self._is_tracing_wrapper(
                node.args[0], imap)
        return False

    def _traced_functions(self, tree, imap) -> list[ast.FunctionDef]:
        # names passed as a function argument to a tracing wrapper call
        wrapped_names: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and (
                    self._is_tracing_wrapper(node.func, imap)
                    or self._is_tracing_wrapper(node, imap)):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        wrapped_names.add(arg.id)
                    elif isinstance(arg, ast.Attribute):
                        wrapped_names.add(arg.attr)

        traced = []
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            is_traced = node.name in wrapped_names
            for deco in node.decorator_list:
                if self._is_tracing_wrapper(deco, imap):
                    is_traced = True
                res = imap.resolve(
                    deco.func if isinstance(deco, ast.Call) else deco)
                if res is not None and res.endswith("register_stage"):
                    is_traced = True
            if is_traced:
                traced.append(node)
        return traced

    def _check_traced(self, fn, imap, path) -> list[Finding]:
        local_names = {a.arg for a in [*fn.args.args, *fn.args.posonlyargs,
                                       *fn.args.kwonlyargs]}
        if fn.args.vararg:
            local_names.add(fn.args.vararg.arg)
        if fn.args.kwarg:
            local_names.add(fn.args.kwarg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                local_names.add(node.id)

        findings = []
        ctx = f"traced function '{fn.name}'"
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kw = "global" if isinstance(node, ast.Global) else "nonlocal"
                findings.append(self.finding(
                    path, node,
                    f"{kw} statement in {ctx}: rebinding outer state is a "
                    f"trace-time side effect",
                ))
            if not isinstance(node, ast.Call):
                continue
            res = imap.resolve(node.func)
            if res == "print":
                findings.append(self.finding(
                    path, node,
                    f"print() in {ctx} runs at trace time only; use "
                    f"jax.debug.print for per-step output",
                ))
            elif res in _TIME_CALLS:
                findings.append(self.finding(
                    path, node,
                    f"{res}() in {ctx} is evaluated once at trace time, "
                    f"not per step",
                ))
            elif res is not None and (
                    res.endswith("datetime.now")
                    or res.endswith("datetime.utcnow")
                    or res.endswith("date.today")):
                findings.append(self.finding(
                    path, node,
                    f"{res}() in {ctx} is evaluated once at trace time, "
                    f"not per step",
                ))
            elif (res is not None and res.startswith("numpy.")
                    and res not in _NUMPY_OK):
                findings.append(self.finding(
                    path, node,
                    f"{res}() in {ctx}: numpy ops on traced values "
                    f"force host sync or fail; use jnp",
                ))
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id not in local_names):
                findings.append(self.finding(
                    path, node,
                    f"mutation of '{node.func.value.id}.{node.func.attr}' "
                    f"in {ctx}: the target is defined outside the traced "
                    f"function, so this mutates once at trace time",
                ))
        return findings


# ---------------------------------------------------------------------------
# raw-shard-map / raw-mesh (the compat-shim hygiene rules)
# ---------------------------------------------------------------------------
@register_rule
class RawShardMapRule(Rule):
    id = "raw-shard-map"
    doc = (
        "Direct use of jax.shard_map / jax.experimental.shard_map outside "
        "the compat helper. Route through distributed.sharding.shard_map, "
        "which handles the check_rep/check_vma and ambient-mesh API drift "
        "across jax versions in one place (ROADMAP hygiene item)."
    )
    exempt_paths = ("src/repro/distributed/sharding.py",)

    _TARGETS = ("jax.shard_map", "jax.experimental.shard_map")

    def check(self, tree, source, path):
        imap = ImportMap.of(tree)
        findings = []
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                hits = (
                    node.module.startswith("jax.experimental.shard_map")
                    or (node.module in ("jax", "jax.experimental")
                        and any(a.name == "shard_map"
                                for a in node.names))
                )
                if hits:
                    findings.append(self.finding(
                        path, node,
                        "import of raw shard_map; use "
                        "repro.distributed.sharding.shard_map",
                    ))
            elif isinstance(node, (ast.Attribute, ast.Name)):
                res = imap.resolve(node)
                if res is not None and (
                        res in self._TARGETS
                        or res.startswith("jax.experimental.shard_map.")):
                    findings.append(self.finding(
                        path, node,
                        f"raw {res}; use "
                        f"repro.distributed.sharding.shard_map",
                    ))
        # attribute chains nest (jax.experimental.shard_map resolves at
        # several depths): dedup per line
        seen: set[tuple[int, str]] = set()
        out = []
        for f in findings:
            if (f.line, f.rule) not in seen:
                seen.add((f.line, f.rule))
                out.append(f)
        return out


@register_rule
class RawMeshRule(Rule):
    id = "raw-mesh"
    doc = (
        "Direct jax.sharding.Mesh(...) / jax.make_mesh(...) construction "
        "outside launch.mesh. Use make_local_mesh / make_production_mesh / "
        "make_mesh_from_plan + ambient_mesh, which pin AxisType and the "
        "set_mesh-vs-context-manager drift across jax versions (ROADMAP "
        "hygiene item). Importing Mesh for type annotations is fine; "
        "calling it is not."
    )
    exempt_paths = ("src/repro/launch/mesh.py",)

    _TARGETS = {"jax.sharding.Mesh", "jax.make_mesh",
                "jax.experimental.mesh_utils.create_device_mesh",
                "jax.interpreters.pxla.Mesh"}

    def check(self, tree, source, path):
        imap = ImportMap.of(tree)
        findings = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            res = imap.resolve(node.func)
            if res in self._TARGETS:
                findings.append(self.finding(
                    path, node,
                    f"raw mesh construction {res}(...); use the "
                    f"repro.launch.mesh helpers",
                ))
        return findings


# ---------------------------------------------------------------------------
# dtype-discipline (packed-key uint32 math must keep explicit widths)
# ---------------------------------------------------------------------------
_ARRAY_CTORS = {"arange", "zeros", "ones", "full", "empty"}
_NUMPY_MODULES = {"numpy", "jax.numpy"}
_WIDTH_CASTS = {
    "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
}


def _numpy_ctor(res: str | None) -> str | None:
    """'jax.numpy.arange' -> 'arange' if it is an array constructor."""
    if res is None or "." not in res:
        return None
    mod, name = res.rsplit(".", 1)
    if mod in _NUMPY_MODULES and name in _ARRAY_CTORS:
        return name
    return None


def _explicit_width(node: ast.AST, imap: ImportMap) -> str | None:
    """The integer width an expression explicitly commits to, if any:
    ``jnp.uint32(x)`` -> 'uint32', ``x.astype(jnp.int32)`` -> 'int32'."""
    if not isinstance(node, ast.Call):
        return None
    res = imap.resolve(node.func)
    if res is not None and "." in res:
        mod, name = res.rsplit(".", 1)
        if mod in _NUMPY_MODULES and name in _WIDTH_CASTS:
            return name
    if (isinstance(node.func, ast.Attribute)
            and node.func.attr == "astype" and node.args):
        res = imap.resolve(node.args[0])
        if res is not None and "." in res:
            mod, name = res.rsplit(".", 1)
            if mod in _NUMPY_MODULES and name in _WIDTH_CASTS:
                return name
    return None


@register_rule
class DtypeDisciplineRule(Rule):
    id = "dtype-discipline"
    doc = (
        "In the packed-key modules (core/, kernels/, engine/stages.py): "
        "array constructors must pass an explicit dtype= (default widths "
        "drift with x64 mode), and arithmetic must not mix two different "
        "explicitly-cast integer widths without an astype — silent "
        "promotion breaks uint32 packed-key math the fused build kernel "
        "depends on."
    )
    paths = ("src/repro/core", "src/repro/kernels",
             "src/repro/engine/stages.py")

    def check(self, tree, source, path):
        imap = ImportMap.of(tree)
        findings = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                ctor = _numpy_ctor(imap.resolve(node.func))
                if ctor is not None and not any(
                        kw.arg == "dtype" for kw in node.keywords):
                    # a positional dtype is legal for some ctors; accept a
                    # trailing positional arg that names a dtype
                    if not any(_is_dtype_expr(a, imap) for a in node.args):
                        findings.append(self.finding(
                            path, node,
                            f"{ctor}() without explicit dtype= in "
                            f"packed-key code; default integer widths "
                            f"depend on x64 mode",
                        ))
            elif isinstance(node, ast.BinOp):
                lw = _explicit_width(node.left, imap)
                rw = _explicit_width(node.right, imap)
                if lw and rw and lw != rw:
                    findings.append(self.finding(
                        path, node,
                        f"arithmetic mixes explicit {lw} and {rw} "
                        f"operands without astype; pick one width",
                    ))
        return findings


def _is_dtype_expr(node: ast.AST, imap: ImportMap) -> bool:
    """Does this argument expression explicitly name a dtype?  Covers
    ``jnp.int32``, ``x.dtype`` / ``x.vals.dtype`` (inheriting a width is
    explicit), a ``dtype``-named variable threading a parameter through,
    and ``jnp.dtype(...)`` calls."""
    if isinstance(node, ast.Attribute) and node.attr == "dtype":
        return True
    if isinstance(node, ast.Name) and "dtype" in node.id:
        return True
    if isinstance(node, ast.Call):
        return _is_dtype_expr(node.func, imap)
    res = imap.resolve(node)
    if res is None or "." not in res:
        return False
    mod, name = res.rsplit(".", 1)
    return mod in _NUMPY_MODULES and (
        name in _WIDTH_CASTS or name in ("float32", "float64", "float16",
                                         "bfloat16", "bool_", "dtype"))


# ---------------------------------------------------------------------------
# thread-shared-state
# ---------------------------------------------------------------------------
@register_rule
class ThreadSharedStateRule(Rule):
    id = "thread-shared-state"
    doc = (
        "In the threaded engine modules (engine/prefetch.py, "
        "engine/policies.py, the serve/ daemon): a closure that runs on "
        "a worker thread "
        "mutates an attribute the consumer thread also reads, outside a "
        "held lock. Wrap the write in `with <lock>:` — the GIL orders "
        "single bytecodes, not read-modify-write sequences like `+=`."
    )
    paths = ("src/repro/engine/prefetch.py",
             "src/repro/engine/policies.py",
             "src/repro/serve")

    def check(self, tree, source, path):
        findings: list[Finding] = []
        self._visit(tree, depth=0, under_lock=False, findings=findings,
                    path=path)
        return findings

    def _visit(self, node, depth, under_lock, findings, path):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            depth += 1
            under_lock = False  # a new thread entry point starts unlocked
        elif isinstance(node, ast.With):
            if any(self._is_lock(item.context_expr)
                   for item in node.items):
                under_lock = True
        elif depth >= 2 and not under_lock and isinstance(
                node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Attribute):
                    base = t.value
                    base_name = (base.id if isinstance(base, ast.Name)
                                 else "<expr>")
                    findings.append(self.finding(
                        path, node,
                        f"'{base_name}.{t.attr}' is mutated from a "
                        f"worker-thread closure outside a lock; wrap the "
                        f"write in `with <lock>:`",
                    ))
        for child in ast.iter_child_nodes(node):
            self._visit(child, depth, under_lock, findings, path)

    @staticmethod
    def _is_lock(expr: ast.AST) -> bool:
        name = None
        if isinstance(expr, ast.Attribute):
            name = expr.attr
        elif isinstance(expr, ast.Name):
            name = expr.id
        elif isinstance(expr, ast.Call):
            return ThreadSharedStateRule._is_lock(expr.func)
        return name is not None and "lock" in name.lower()


# ---------------------------------------------------------------------------
# swallowed-exception
# ---------------------------------------------------------------------------
_BROAD_EXC = {"Exception", "BaseException"}
_HANDLER_VERBS = ("warn", "log", "record", "fail")


def _broad_handler_types(handler: ast.ExceptHandler) -> list[str]:
    """The broad classes this handler catches: bare ``except:``, Exception,
    BaseException — named directly or inside a tuple.  A handler for a
    *specific* exception type is a deliberate decision and never flagged."""
    t = handler.type
    if t is None:
        return ["<bare>"]
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    broad = []
    for e in elts:
        name = e.id if isinstance(e, ast.Name) else (
            e.attr if isinstance(e, ast.Attribute) else None)
        if name in _BROAD_EXC:
            broad.append(name)
    return broad


@register_rule
class SwallowedExceptionRule(Rule):
    id = "swallowed-exception"
    doc = (
        "In the fault-tolerant engine modules (src/repro/engine/): a bare/"
        "Exception/BaseException handler whose body neither re-raises, nor "
        "reads the bound exception, nor calls a warn/log/record/fail "
        "handler. The engine's degradation contract is *honest* "
        "accounting — every survived failure must be recorded (counters, "
        "_record_failure, warnings.warn) or re-raised; a silent `except "
        "Exception: pass` turns a fault into a lie about coverage."
    )
    paths = ("src/repro/engine", "src/repro/serve")

    def check(self, tree, source, path):
        findings = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            broad = _broad_handler_types(node)
            if not broad:
                continue
            if self._handles(node):
                continue
            caught = ", ".join(broad).replace("<bare>", "everything")
            findings.append(self.finding(
                path, node,
                f"broad except ({caught}) drops the error on the floor: "
                f"no raise, no use of the caught exception, no "
                f"warn/log/record call — record the failure or re-raise",
            ))
        return findings

    @staticmethod
    def _handles(handler: ast.ExceptHandler) -> bool:
        """Does the handler body do *something* with the error?  A raise
        (including bare re-raise), any read of the bound exception name,
        or a call whose name contains a handling verb all count."""
        for node in ast.walk(handler):
            if isinstance(node, ast.Raise):
                return True
            if (handler.name and isinstance(node, ast.Name)
                    and node.id == handler.name
                    and isinstance(node.ctx, ast.Load)):
                return True
            if isinstance(node, ast.Call):
                f = node.func
                name = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else "")
                if any(v in name.lower() for v in _HANDLER_VERBS):
                    return True
        return False
