"""Repo-aware static analysis: machine-checked engine invariants.

The engine leans on contracts no generic linter understands — donated
buffers are unobservable after async dispatch, uint32 packed-key arithmetic
must never silently promote, every ``shard_map``/mesh construction must go
through the compat helpers, and prefetcher/ring worker threads may only
touch shared attributes under a lock.  ``repro.analysis`` encodes each
contract as an AST rule over the repo's own source and fails CI on any
non-baselined finding:

    python -m repro.analysis src tests benchmarks

See DESIGN.md "Static analysis & invariants" for the rule catalogue, the
suppression syntax (``# repro-lint: disable=<rule>``), and the baseline
workflow.
"""

from repro.analysis.framework import (
    Finding,
    Rule,
    RULE_REGISTRY,
    analyze_file,
    analyze_source,
    iter_python_files,
    register_rule,
    scan_paths,
)
from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)

__all__ = [
    "Finding",
    "Rule",
    "RULE_REGISTRY",
    "analyze_file",
    "analyze_source",
    "iter_python_files",
    "load_baseline",
    "register_rule",
    "scan_paths",
    "write_baseline",
]
