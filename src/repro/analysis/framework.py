"""Rule registry, suppression comments, and the file/source analyzers.

A Rule owns one machine-checked invariant: it gets the parsed AST plus the
source text of a file and returns Findings.  Rules register themselves with
``@register_rule`` so the CLI, the fixture tests, and the baseline check all
see the same catalogue — there is no second list to forget to update.

Suppression is per line and per rule: a trailing ``# repro-lint:
disable=<rule>[,<rule>...]`` comment silences those rules on that line (or,
on its own line, on the line below — for lines too long to carry a
comment).  ``disable-file=<rule>`` anywhere in the first ten lines silences
a rule for the whole file.  Suppressions are deliberate and visible in
review, which is the point: violating an engine contract must leave a mark.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Iterator

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_,\- ]+)"
)
_FILE_SCOPE_LINES = 10


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str  # repo-relative, '/'-separated
    line: int  # 1-indexed
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def key(self) -> tuple[str, int, str]:
        """Identity used for baseline matching (message text may evolve
        without invalidating a grandfathered entry)."""
        return (self.path, self.line, self.rule)


class Rule:
    """One invariant: subclass, set ``id``/``doc``, implement ``check``.

    ``paths`` (optional tuple of repo-relative prefixes or exact paths)
    restricts where the rule applies — e.g. dtype discipline only polices
    the packed-key modules.  ``exempt_paths`` carves out the helper modules
    a rule exists to protect (the compat shims themselves may touch the raw
    jax API).
    """

    id: str = ""
    doc: str = ""
    paths: tuple[str, ...] = ()  # empty = everywhere
    exempt_paths: tuple[str, ...] = ()

    def applies_to(self, path: str) -> bool:
        path = path.replace("\\", "/")
        if any(_match(path, p) for p in self.exempt_paths):
            return False
        if not self.paths:
            return True
        return any(_match(path, p) for p in self.paths)

    def check(self, tree: ast.AST, source: str, path: str) -> list[Finding]:
        raise NotImplementedError

    def finding(self, path: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(path=path, line=int(line), rule=self.id,
                       message=message)


def _match(path: str, pattern: str) -> bool:
    """Prefix match on path components ('src/repro/core' matches the dir,
    'src/repro/engine/stages.py' matches exactly that file)."""
    return path == pattern or path.startswith(pattern.rstrip("/") + "/")


RULE_REGISTRY: dict[str, Rule] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator: instantiate and register a Rule by its id."""
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in RULE_REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULE_REGISTRY[cls.id] = cls()
    return cls


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------
def parse_suppressions(source: str) -> tuple[dict[int, set[str]], set[str]]:
    """-> ({line: {rule, ...}}, {file-wide rule, ...}).

    A ``disable`` comment on a line with code suppresses that line; on a
    line of its own it also suppresses the next line.
    """
    by_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        kind = m.group(1)
        rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
        if kind == "disable-file":
            if lineno <= _FILE_SCOPE_LINES:
                file_wide |= rules
            continue
        by_line.setdefault(lineno, set()).update(rules)
        if text[: m.start()].strip() == "":  # comment-only line
            by_line.setdefault(lineno + 1, set()).update(rules)
    return by_line, file_wide


def _suppressed(f: Finding, by_line: dict[int, set[str]],
                file_wide: set[str]) -> bool:
    if f.rule in file_wide or "all" in file_wide:
        return True
    rules = by_line.get(f.line, ())
    return f.rule in rules or "all" in rules


# ---------------------------------------------------------------------------
# analyzers
# ---------------------------------------------------------------------------
def analyze_source(source: str, path: str = "<string>",
                   rules: Iterable[Rule] | None = None,
                   respect_suppressions: bool = True) -> list[Finding]:
    """Run rules over one source string; ``path`` routes path-scoped rules
    (pass the repo-relative path the snippet pretends to live at)."""
    path = path.replace("\\", "/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path=path, line=e.lineno or 1, rule="syntax-error",
                        message=f"file does not parse: {e.msg}")]
    if rules is None:
        rules = RULE_REGISTRY.values()
    findings: list[Finding] = []
    for rule in rules:
        if rule.applies_to(path):
            findings.extend(rule.check(tree, source, path))
    if respect_suppressions:
        by_line, file_wide = parse_suppressions(source)
        findings = [f for f in findings
                    if not _suppressed(f, by_line, file_wide)]
    return sorted(findings)


def analyze_file(file_path: Path, root: Path,
                 rules: Iterable[Rule] | None = None) -> list[Finding]:
    rel = file_path.resolve().relative_to(root.resolve()).as_posix()
    return analyze_source(file_path.read_text(encoding="utf-8"), rel, rules)


_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".pytest_cache",
              "node_modules", ".hypothesis"}


def iter_python_files(paths: Iterable[str | Path],
                      root: Path) -> Iterator[Path]:
    for p in paths:
        p = (root / p) if not Path(p).is_absolute() else Path(p)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not _SKIP_DIRS.intersection(f.parts):
                    yield f


def scan_paths(paths: Iterable[str | Path], root: str | Path,
               rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Analyze every .py file under ``paths`` (relative to ``root``)."""
    root = Path(root)
    findings: list[Finding] = []
    for f in iter_python_files(paths, root):
        findings.extend(analyze_file(f, root, rules))
    return sorted(findings)
