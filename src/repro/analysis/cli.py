"""CLI: ``python -m repro.analysis [paths...]``.

Exit status is the CI contract: 0 when every finding is baselined and no
baseline entry is stale, 1 otherwise.  Stale entries fail too — the
baseline must always match a fresh scan, so it can only shrink as findings
are fixed, never rot.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import baseline as bl
from repro.analysis.framework import RULE_REGISTRY, scan_paths

DEFAULT_PATHS = ("src", "tests", "benchmarks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-aware static analysis (engine-contract lints).",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=".",
                    help="repo root findings are reported relative to "
                         "(default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: "
                         f"<root>/{bl.BASELINE_NAME})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather all current findings into the "
                         "baseline and exit 0 (each entry still needs a "
                         "real justification edited in before review)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULE_REGISTRY):
            rule = RULE_REGISTRY[rid]
            where = ", ".join(rule.paths) if rule.paths else "all files"
            print(f"{rid}  [{where}]")
            print(f"    {rule.doc}")
        return 0

    root = Path(args.root).resolve()
    paths = args.paths or list(DEFAULT_PATHS)
    paths = [p for p in paths if (root / p).exists()
             or Path(p).is_absolute()]
    findings = scan_paths(paths, root)

    baseline_path = Path(args.baseline) if args.baseline else (
        root / bl.BASELINE_NAME)
    if args.write_baseline:
        bl.write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = {} if args.no_baseline else bl.load_baseline(baseline_path)
    new, old, stale = bl.split_by_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "new": [f.__dict__ for f in new],
            "baselined": [f.__dict__ for f in old],
            "stale_baseline_entries": [list(k) for k in stale],
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        for k in stale:
            print(f"{k[0]}:{k[1]}: [{k[2]}] STALE baseline entry — the "
                  f"finding is gone; remove it from {baseline_path.name}")
        print(
            f"repro.analysis: {len(new)} new finding(s), "
            f"{len(old)} baselined, {len(stale)} stale baseline "
            f"entr{'y' if len(stale) == 1 else 'ies'} "
            f"({len(RULE_REGISTRY)} rules)"
        )
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
