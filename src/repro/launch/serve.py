"""Serving driver: batched prefill + decode loop with a KV cache.

Demonstrates the inference path end-to-end on the dev host: requests are
batched, prompts prefill once, then tokens decode step-by-step against the
cache (the decode_32k / long_500k dry-run cells lower exactly this
``decode_step``). Greedy sampling; the loop is host-driven as a real
serving binary would be, with the cache living on device between steps.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as tfm


def generate(params, cfg, prompts: np.ndarray, *, max_new_tokens: int,
             max_seq: int):
    """prompts: [b, prompt_len] int32 -> [b, max_new_tokens] int32."""
    b, plen = prompts.shape
    logits, cache, clen = jax.jit(
        lambda p, t: tfm.prefill(p, t, cfg)
    )(params, jnp.asarray(prompts))
    # grow cache to max_seq
    pad = max_seq - cache["k"].shape[2]
    cache = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        cache,
    )
    decode = jax.jit(lambda p, t, c, l: tfm.decode_step(p, t, c, l, cfg))
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for i in range(max_new_tokens):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok, cache, clen + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return np.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)

    mod = configs.get(args.arch)
    cfg = dataclasses.replace(mod.smoke_config(), dtype="float32")
    params = tfm.init_transformer(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)

    t0 = time.time()
    tokens = generate(
        params, cfg, prompts, max_new_tokens=args.new_tokens,
        max_seq=args.prompt_len + args.new_tokens + 1,
    )
    dt = time.time() - t0
    n = args.batch * args.new_tokens
    print(f"[serve] generated {n} tokens in {dt:.2f}s "
          f"({n/dt:,.0f} tok/s incl. compile)")
    print("[serve] sample:", tokens[0, :16].tolist())
    return tokens


if __name__ == "__main__":
    main()
