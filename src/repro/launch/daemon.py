"""Always-on analytics daemon launcher (``python -m repro.launch.daemon``).

The long-running form of ``launch/ingest.py``: instead of draining a
fixed source, ``--serve`` binds an ingest/query socket and the engine
drains whatever clients stream at it, forever, until SIGTERM/SIGINT or
a client's shutdown message — at which point it finishes everything
already accepted, writes a final checkpoint, and exits cleanly.

    python -m repro.launch.daemon --serve tcp://127.0.0.1:9321 \
        --window-log2 10 --windows-per-batch 8 --policy async_pipelined \
        --rollup-levels 4 --export flags.rpfr \
        --checkpoint-dir ckpts --checkpoint-every 4 --resume

On SIGTERM the drain contract is: stop accepting, process every batch
already queued, flush a final checkpoint at the exact stream cursor,
close every sink handle, exit 0.  Restarting with ``--resume`` while
clients replay the stream from its beginning resumes bit-identically
(the engine fast-forwards past everything the previous run consumed).
"""

from __future__ import annotations

import argparse
import json
import signal

import numpy as np

from repro.core.window import WindowConfig
from repro.engine.faults import FaultPlan, FaultTolerance
from repro.launch.ingest import GEOMETRY_DEFAULTS
from repro.serve.daemon import AnalyticsDaemon


def build_daemon(args) -> AnalyticsDaemon:
    geom = GEOMETRY_DEFAULTS[args.workload]
    cfg = WindowConfig(
        window_log2=args.window_log2 or geom["window_log2"],
        windows_per_batch=args.windows_per_batch
        or geom["windows_per_batch"],
        anonymization=args.anonymization,
        build_kernel=args.build_kernel,
    )
    ft = None
    if args.inject_faults or args.validate_batches or args.quarantine_file:
        plan = (FaultPlan.parse(args.inject_faults)
                if args.inject_faults else None)
        ft = FaultTolerance(
            plan=plan,
            max_retries=args.max_retries,
            on_exhausted=args.on_exhausted,
            validate=args.validate_batches or bool(args.quarantine_file),
            quarantine_path=args.quarantine_file,
            sink_failures=args.sink_failures,
        )
    manager = None
    if args.checkpoint_dir:
        from repro.checkpoint.manager import CheckpointManager

        manager = CheckpointManager(args.checkpoint_dir,
                                    keep=args.keep_checkpoints)
    return AnalyticsDaemon(
        cfg,
        workload=args.workload,
        policy=args.policy,
        rollup_levels=args.rollup_levels,
        rollup_keep=args.rollup_keep,
        export=args.export,
        export_rule=args.export_rule,
        export_threshold=args.export_threshold,
        fault_tolerance=ft,
        checkpoint_manager=manager,
        checkpoint_every=args.checkpoint_every if manager else 0,
        resume=args.resume,
        queue_depth=args.queue_depth,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve", required=True, metavar="ADDR",
                    help="ingest/query address: tcp://host:port (port 0 = "
                         "ephemeral) or unix:///path")
    ap.add_argument("--workload", default="packets",
                    choices=["packets", "flow"])
    ap.add_argument("--policy", default="blocking")
    ap.add_argument("--window-log2", type=int, default=None)
    ap.add_argument("--windows-per-batch", type=int, default=None)
    ap.add_argument("--anonymization", default="feistel",
                    choices=["feistel", "cryptopan", "none"])
    ap.add_argument("--build-kernel", action="store_true")
    ap.add_argument("--queue-depth", type=int, default=8,
                    help="ingest queue bound (backpressure depth)")
    ap.add_argument("--rollup-levels", type=int, default=4,
                    help="power-of-two roll-up hierarchy depth "
                         "(0 disables the roll-up/query API)")
    ap.add_argument("--rollup-keep", type=int, default=4,
                    help="aggregates retained per roll-up level")
    ap.add_argument("--export", default=None, metavar="DEST",
                    help="ExporterSink destination for flagged windows: "
                         "a file path or tcp://host:port / unix://path")
    ap.add_argument("--export-rule", default="zscore",
                    choices=["zscore", "count"])
    ap.add_argument("--export-threshold", type=float, default=3.0)
    ap.add_argument("--inject-faults", default=None, metavar="PLAN")
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--on-exhausted", default="raise",
                    choices=["raise", "skip"])
    ap.add_argument("--validate-batches", action="store_true")
    ap.add_argument("--quarantine-file", default=None,
                    help="dead-letter journal for quarantined batches "
                         "(implies --validate-batches; append-safe across "
                         "--resume)")
    ap.add_argument("--sink-failures", default="raise",
                    choices=["raise", "record"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--keep-checkpoints", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    daemon = build_daemon(args)
    address = daemon.bind(args.serve)
    # flush=True: subprocess drivers (tests, CI) block on this line to
    # learn the resolved ephemeral port
    print(f"serving on {address}", flush=True)

    def _terminate(signum, frame):
        daemon.shutdown()

    signal.signal(signal.SIGTERM, _terminate)
    signal.signal(signal.SIGINT, _terminate)

    report = daemon.serve_forever()
    results = daemon.finalize()
    summary = {
        "address": address,
        "batches": report.batches,
        "packets": report.packets,
        "checkpoints_written": report.checkpoints_written,
        "resumed_from": report.resumed_from,
    }
    stats = results.get("stats")
    if isinstance(stats, dict):
        scalars = {}
        for k, v in stats.items():
            if k == "per_batch":
                continue
            arr = np.asarray(v)
            if arr.ndim == 0:
                scalars[k] = int(arr)
        summary["stats"] = scalars
    if "exporter" in results:
        summary["exported"] = results["exporter"]["exported"]
    print(json.dumps(summary), flush=True)
    return report


if __name__ == "__main__":
    main()
