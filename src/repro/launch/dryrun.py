import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, and extract the roofline terms.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first initialization, and the dry-run needs 512
placeholder CPU devices to build the 2x16x16 production mesh.

Per cell this runs up to two compiles:
  * production program (scans as while loops, real microbatching):
    proves the sharding config compiles at scale + per-device memory stats;
  * costing program (scans unrolled, one microbatch, scaled by cost_scale):
    XLA's cost model counts a while body once regardless of trip count, so
    the roofline flops/bytes/collectives come from the unrolled variant.

Results are cached as JSON per (arch, shape, mesh) under --out, so the full
40-cell sweep is restartable and the roofline table (benchmarks/roofline.py)
is a pure read of the cache.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.launch.mesh import ambient_mesh

# TPU v5e constants for the roofline terms
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-device injection)

# result type is either a single `dtype[dims]{layout}` or a tuple
# `(dtype[dims]{..}, /*index=5*/ dtype[dims]{..}, ...)` for variadic
# collectives; lhs is matched within the line only (HLO is one op per line)
_COLLECTIVE_RE = re.compile(
    r"=\s+(?P<lhs>[^\n]+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, from post-SPMD HLO.

    Result-shape convention per op (ring algorithms, per-device traffic):
      all-gather: result bytes (each device receives ~the full result)
      all-reduce: 2x result bytes (reduce-scatter + all-gather phases)
      reduce-scatter: result bytes x group size (sends its full input)
      all-to-all / collective-permute: result bytes
    """
    totals = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = sum(
            _shape_bytes(dt, dims)
            for dt, dims in _SHAPE_RE.findall(m.group("lhs"))
        )
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end]
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        group = int(gm.group(2)) if gm else 1
        if op == "all-reduce":
            moved = 2.0 * nbytes * (group - 1) / max(group, 1)
        elif op == "all-gather":
            moved = nbytes * (group - 1) / max(group, 1)
        elif op == "reduce-scatter":
            moved = nbytes * (group - 1)
        else:
            moved = nbytes
        totals[op] += moved
        counts[op] += 1
    return {
        "per_device_bytes": sum(totals.values()),
        "by_op_bytes": totals,
        "counts": counts,
    }


def build_mesh(which: str):
    from repro.launch.mesh import make_production_mesh

    return make_production_mesh(multi_pod=(which == "multi"))


def _shardings(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             *, skip_costing: bool = False) -> dict:
    from repro import configs

    mod = configs.get(arch_id)
    mesh = build_mesh(mesh_kind)
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "n_devices": mesh.size,
        "status": "ok",
    }

    # ---- production compile: proves sharding + memory at scale -------------
    cell = mod.build_cell(shape_name, mesh)
    rec["kind"] = cell.kind
    rec["note"] = cell.note
    rec["model_flops_per_step"] = cell.model_flops_per_step
    t0 = time.time()
    with ambient_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=_shardings(cell.in_specs, mesh),
            out_shardings=(
                _shardings(cell.out_specs, mesh)
                if cell.out_specs is not None else None
            ),
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory_per_device"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }

    # ---- costing compile: unrolled variant for flops/bytes/collectives -----
    # For layer-stacked (transformer) cells, lower shallow variants at
    # L=1 and L=2 and extrapolate affinely: per-step cost is exactly
    # a + b*L for a homogeneous stack, and compile time stays O(1) in L.
    def _compile_cost(c):
        with ambient_mesh(mesh):
            return jax.jit(
                c.fn,
                in_shardings=_shardings(c.in_specs, mesh),
                out_shardings=(
                    _shardings(c.out_specs, mesh)
                    if c.out_specs is not None else None
                ),
            ).lower(*c.args).compile()

    def _measure(compiled_prog, scale):
        ca = compiled_prog.cost_analysis() or {}
        coll = parse_collective_bytes(compiled_prog.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)) * scale,
            "bytes": float(ca.get("bytes accessed", 0.0)) * scale,
            "coll": coll["per_device_bytes"] * scale,
            "by_op": {k: v * scale for k, v in coll["by_op_bytes"].items()},
            "counts": coll["counts"],
        }

    t1 = time.time()
    if skip_costing:
        m = _measure(compiled, cell.cost_scale)
        rec["cost_scale"] = cell.cost_scale
    elif getattr(mod, "FAMILY", None) == "transformer":
        n_layers = mod.model_config().n_layers
        c1 = mod.build_cell(shape_name, mesh, costing=True, costing_layers=1)
        c2 = mod.build_cell(shape_name, mesh, costing=True, costing_layers=2)
        rec["cost_scale"] = c1.cost_scale
        m1 = _measure(_compile_cost(c1), c1.cost_scale)
        m2 = _measure(_compile_cost(c2), c2.cost_scale)

        def extrap(a, b):
            # affine in depth when the lowered program is layer-homogeneous
            # (b >= a); XLA occasionally picks a different sharding strategy
            # at L=1 (e.g. all-gathering a dispatch buffer it keeps
            # replicated at L=2), breaking homogeneity — fall back to
            # treating the L=2 program as fully layer-proportional, which
            # over-counts fixed parts but stays sane and positive.
            if b >= a:
                return a + (n_layers - 1) * (b - a)
            return b * n_layers / 2.0

        m = {
            "flops": extrap(m1["flops"], m2["flops"]),
            "bytes": extrap(m1["bytes"], m2["bytes"]),
            "coll": sum(
                extrap(m1["by_op"][k], m2["by_op"][k]) for k in m1["by_op"]
            ),
            "by_op": {k: extrap(m1["by_op"][k], m2["by_op"][k])
                      for k in m1["by_op"]},
            "counts": m2["counts"],
        }
        rec["costing_method"] = f"affine_extrapolation_L1_L2_to_{n_layers}"
    else:
        cost_cell = mod.build_cell(shape_name, mesh, costing=True)
        rec["cost_scale"] = cost_cell.cost_scale
        m = _measure(_compile_cost(cost_cell), cost_cell.cost_scale)
    rec["costing_compile_s"] = round(time.time() - t1, 2)

    flops_dev = m["flops"]
    bytes_dev = m["bytes"]
    coll_dev = m["coll"]

    n_dev = mesh.size
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    rec.update({
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collectives": {
                "by_op_bytes": m["by_op"],
                "counts": m["counts"],
            },
        },
        "global": {
            "hlo_flops": flops_dev * n_dev,
            "hlo_bytes": bytes_dev * n_dev,
            "collective_bytes": coll_dev * n_dev,
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "step_s_lower_bound": max(compute_s, memory_s, collective_s),
        },
        "model_flops_ratio": (
            cell.model_flops_per_step / (flops_dev * n_dev)
            if flops_dev else None
        ),
    })
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-costing", action="store_true")
    args = ap.parse_args()

    from repro import configs

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cells = configs.all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch_id, shape_name in cells:
        for mesh_kind in meshes:
            slug = f"{arch_id}__{shape_name}__{mesh_kind}".replace("/", "_")
            path = outdir / f"{slug}.json"
            if path.exists() and not args.force:
                n_skip += 1
                continue
            print(f"=== {arch_id} x {shape_name} [{mesh_kind}] ===",
                  flush=True)
            try:
                rec = run_cell(arch_id, shape_name, mesh_kind,
                               skip_costing=args.skip_costing)
                r = rec["roofline"]
                mem = rec.get("memory_per_device", {})
                print(
                    f"  compile {rec['compile_s']}s | "
                    f"mem/dev {mem.get('total_bytes', 0)/1e9:.2f}GB | "
                    f"compute {r['compute_s']*1e3:.3f}ms "
                    f"memory {r['memory_s']*1e3:.3f}ms "
                    f"collective {r['collective_s']*1e3:.3f}ms "
                    f"-> {r['dominant']}", flush=True,
                )
                n_ok += 1
            except Exception as e:
                rec = {
                    "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"  FAILED: {e}", flush=True)
                n_fail += 1
            path.write_text(json.dumps(rec, indent=2))
    print(f"done: {n_ok} ok, {n_fail} failed, {n_skip} cached")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
