"""End-to-end training driver.

Wires together: config registry -> data pipeline (prefetched, shard-aware)
-> sharded init -> microbatched train step -> checkpoint manager (atomic,
async, keep-N) -> heartbeat/straggler policy. Works identically on the dev
host (1 CPU device) and a pod (set the mesh flags); the e2e example trains a
reduced LM for a few hundred steps on CPU.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
      --preset smoke --steps 50 --ckpt-dir /tmp/run1
Restart with the same command: the latest checkpoint (params, optimizer,
data-iterator state) is picked up automatically.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.configs import base as cfg_base
from repro.data.pipeline import Prefetcher
from repro.data.tokens import TokenStream
from repro.distributed import sharding as shrules
from repro.distributed.fault import HeartbeatMonitor, StragglerPolicy
from repro.launch.mesh import (ambient_mesh, make_local_mesh,
                               make_production_mesh)


def build_lm_trainer(arch_id: str, preset: str, mesh, *,
                     global_batch: int, seq_len: int):
    mod = configs.get(arch_id)
    cfg = mod.smoke_config() if preset == "smoke" else mod.model_config()
    if preset == "smoke":
        cfg = dataclasses.replace(cfg, dtype="float32")
    step, opt = cfg_base.make_lm_train_step(cfg, n_micro=2)

    def init_state(key):
        from repro.models.transformer import init_transformer

        params = init_transformer(key, cfg)
        return {"params": params, "opt": opt.init(params)}

    pspecs_of = lambda st: {
        "params": shrules.param_specs(st["params"], "transformer"),
        "opt": shrules.opt_state_specs(
            shrules.param_specs(st["params"], "transformer"), st["opt"]
        ),
    }
    stream = TokenStream(seed=0, vocab_size=cfg.vocab_size,
                         batch=global_batch, seq_len=seq_len)
    return cfg, step, init_state, pspecs_of, stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    cfg, step, init_state, pspecs_of, stream = build_lm_trainer(
        args.arch, args.preset, mesh,
        global_batch=args.global_batch, seq_len=args.seq_len,
    )

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    monitor = HeartbeatMonitor(n_hosts=1)
    policy = StragglerPolicy(monitor)

    with ambient_mesh(mesh):
        state_abstract = jax.eval_shape(init_state, jax.random.PRNGKey(0))
        specs = pspecs_of(state_abstract)
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        init_jit = jax.jit(init_state, out_shardings=shardings)

        # restart path: restore params/opt + exact data-iterator position
        restored, meta = mgr.restore(state_abstract)
        if restored is not None:
            state = jax.device_put(restored, shardings)
            stream = TokenStream.from_state(meta["stream"])
            start_step = meta["step"]
            print(f"[train] restored step {start_step} from {args.ckpt_dir}")
        else:
            state = init_jit(jax.random.PRNGKey(0))
            start_step = 0

        dp = shrules.batch_axes_for(args.global_batch, mesh)
        batch_sharding = NamedSharding(mesh, P(dp, None))

        def place(np_batch):
            tokens, labels = np_batch
            return {
                "tokens": jax.device_put(tokens, batch_sharding),
                "labels": jax.device_put(labels, batch_sharding),
            }

        step_jit = jax.jit(step, donate_argnums=(0,))

        t_start = time.time()
        losses = []
        # context manager: the token stream is infinite, so the loop never
        # exhausts the prefetcher — without close() its worker thread
        # outlives the run (the thread-leak fixture fails on exactly this)
        with Prefetcher(stream, depth=2, transform=place) as it:
            for i in range(start_step, args.steps):
                batch = next(it)
                t0 = time.time()
                state, metrics = step_jit(state, batch)
                metrics = jax.block_until_ready(metrics)
                dt = time.time() - t0
                monitor.beat(0, i, dt)
                decision = policy.evaluate()
                if decision.action != "proceed":  # pragma: no cover
                    print(f"[fault] {decision}")
                losses.append(float(metrics["loss"]))
                if (i + 1) % args.log_every == 0:
                    tps = args.global_batch * args.seq_len / dt
                    print(f"[train] step {i+1} loss {losses[-1]:.4f} "
                          f"({dt*1e3:.0f} ms, {tps:,.0f} tok/s)")
                if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
                    mgr.save_async(i + 1, state,
                                   meta={"stream": stream.state(),
                                         "arch": args.arch})
        mgr.wait()
        print(f"[train] done: {args.steps - start_step} steps in "
              f"{time.time()-t_start:.1f}s; loss {losses[0] if losses else 0:.3f}"
              f" -> {losses[-1] if losses else 0:.3f}")
        return losses


if __name__ == "__main__":
    main()
