"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh():
    """Whatever this host has (1 CPU device in the dev container)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_mesh_from_plan(shape, axes):
    """Mesh from an elastic re-plan (distributed.fault.plan_mesh)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
