"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: older releases have no
    ``jax.sharding.AxisType`` (all axes are implicitly Auto there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (1 CPU device in the dev container)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))


def make_mesh_from_plan(shape, axes):
    """Mesh from an elastic re-plan (distributed.fault.plan_mesh)."""
    return _make_mesh(tuple(shape), tuple(axes))


def ambient_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on newer jax; older releases use the Mesh object's own
    context manager (explicit NamedShardings carry the mesh anyway).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
