"""Traffic-matrix ingest service: the paper's workload as a launcher.

A thin CLI over ``repro.engine.TrafficEngine`` (see DESIGN.md for the
Source -> Stage -> Sink architecture and the execution policies).  Three
modes, mapping 1:1 onto engine policies:

* ``--mode blocking``   — GraphBLAS-only (paper Fig. 2, red curve): pure
  build throughput over batches of windows.
* ``--mode stream``     — GraphBLAS+IO (paper Fig. 2, blue curve): producer
  thread materializes/transfers packets while the device builds the previous
  batch (double-buffered).
* ``--mode distributed``— the multi-pod path: windows shard across the mesh,
  per-device builds, and an EXACT global merge by row-block all_to_all
  (each device becomes the owner of a 2^32/n_dev slice of source-address
  space — the 2D decomposition from DESIGN.md). Exact distinct-source /
  distinct-link counts fall out because every (row) lives on exactly one
  owner.
"""

from __future__ import annotations

import argparse

from repro.core.window import WindowConfig
from repro.engine import ShardedPolicy, StatsAccumulator, TrafficEngine

# Re-exported for existing callers/tests; implementation lives in the engine.
from repro.engine.sharded import make_exact_ingest_step  # noqa: F401


def run_paper_mode(mode: str, *, window_log2: int = 17,
                   windows_per_batch: int = 64, n_batches: int = 8,
                   anonymization: str = "feistel", kind: str = "uniform",
                   use_kernel: bool = False):
    """Run one Fig.-2 mode through the engine; returns its EngineReport."""
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)
    policy = "double_buffered" if mode == "stream" else "blocking"
    # Fig.-2 comparability: time build+merge only, like the paper.
    engine = TrafficEngine(cfg, policy=policy,
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))
    # one extra leading batch absorbs jit compile (excluded from timing)
    return engine.run(kind, n_batches=n_batches + 1, seed=0, warmup_items=1)


def run_distributed(mesh, *, window_log2: int = 17,
                    windows_per_batch: int | None = None,
                    n_batches: int = 1, anonymization: str = "feistel",
                    kind: str = "uniform"):
    """The sharded policy on ``mesh``; windows_per_batch defaults to
    2 windows per device."""
    wpb = windows_per_batch or mesh.size * 2
    cfg = WindowConfig(window_log2=window_log2, windows_per_batch=wpb,
                       anonymization=anonymization)
    engine = TrafficEngine(cfg, policy=ShardedPolicy(mesh),
                           sinks=[StatsAccumulator()])
    report = engine.run(kind, n_batches=n_batches, seed=0)
    return report, engine.finalize()["stats"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="blocking",
                    choices=["blocking", "stream", "distributed"])
    ap.add_argument("--window-log2", type=int, default=17)
    ap.add_argument("--windows-per-batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--traffic", default="uniform",
                    choices=["uniform", "zipf"])
    ap.add_argument("--anonymization", default="feistel",
                    choices=["feistel", "cryptopan", "none"])
    args = ap.parse_args(argv)

    if args.mode == "distributed":
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        rep, totals = run_distributed(
            mesh, window_log2=args.window_log2, n_batches=args.batches,
            anonymization=args.anonymization, kind=args.traffic,
        )
        print(f"[ingest/distributed] {rep.summary()} (incl. compile)")
        print({k: int(v) for k, v in totals.items()
               if getattr(v, "ndim", 1) == 0 or isinstance(v, int)})
        return rep

    rep = run_paper_mode(
        args.mode, window_log2=args.window_log2,
        windows_per_batch=args.windows_per_batch, n_batches=args.batches,
        anonymization=args.anonymization, kind=args.traffic,
    )
    label = "GraphBLAS+IO" if args.mode == "stream" else "GraphBLAS only"
    print(f"[ingest/{label}] {rep.packets:,} packets, "
          f"{rep.elapsed_s:.2f}s -> {rep.packets_per_second:,.0f} pkt/s")
    return rep


if __name__ == "__main__":
    main()
