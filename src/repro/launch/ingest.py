"""Traffic-matrix ingest service: the paper's workload as a launcher.

Three modes:

* ``--mode blocking``   — GraphBLAS-only (paper Fig. 2, red curve): pure
  build throughput over batches of windows.
* ``--mode stream``     — GraphBLAS+IO (paper Fig. 2, blue curve): producer
  thread materializes/transfers packets while the device builds the previous
  batch (double-buffered).
* ``--mode distributed``— the multi-pod path: windows shard across the mesh,
  per-device builds, and an EXACT global merge by row-block all_to_all
  (each device becomes the owner of a 2^32/n_dev slice of source-address
  space — the 2D decomposition from DESIGN.md). Exact distinct-source /
  distinct-link counts fall out because every (row) lives on exactly one
  owner. This is the beyond-baseline version of the ingest_* dry-run cells.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import analytics, stream
from repro.core.build import matrix_build
from repro.core.hypersparse import SENTINEL
from repro.core.window import WindowConfig, process_batch
from repro.data.packets import traffic_batches
from repro.distributed import sharding as shrules


# ---------------------------------------------------------------------------
# exact distributed merge: route entries to row-block owners via all_to_all
# ---------------------------------------------------------------------------
def _route_entries(rows, cols, vals, valid, n_dev: int, cap_out: int):
    """Bucket entries by owner device (row-block) into [n_dev, cap_out]."""
    bits = int(np.log2(n_dev))
    if bits == 0:
        owner = jnp.zeros(rows.shape, jnp.int32)
    else:
        owner = (rows >> jnp.uint32(32 - bits)).astype(jnp.int32)
    owner = jnp.where(valid, owner, n_dev)
    # rank within each owner bucket (stable by entry order)
    order = jnp.argsort(owner, stable=True)
    so = owner[order]
    n = rows.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    run_start = jax.lax.cummax(jnp.where(first, iota, 0), axis=0)
    rank = iota - run_start
    keep = rank < cap_out
    slot = jnp.where(keep, so * cap_out + rank, n_dev * cap_out)

    def scatter(x, fill):
        buf = jnp.full((n_dev * cap_out,), fill, x.dtype)
        return buf.at[slot].set(x[order], mode="drop").reshape(
            n_dev, cap_out
        )

    kept_valid = (keep & (so < n_dev)).sum().astype(jnp.int32)
    overflow = valid.sum().astype(jnp.int32) - kept_valid
    return (
        scatter(rows, SENTINEL),
        scatter(cols, SENTINEL),
        scatter(vals, jnp.zeros((), vals.dtype)),
        overflow,
    )


def make_exact_ingest_step(mesh, cfg: WindowConfig, *,
                           route_capacity_factor: float = 2.0):
    """shard_map step: local builds -> all_to_all row-block exchange ->
    owner-local dedup -> exact global analytics."""
    axes = shrules.all_axes(mesh)
    flat = axes if len(axes) > 1 else axes[0]
    n_dev = mesh.size

    def shard_fn(windows_local):
        merged, ovf = process_batch(windows_local, cfg)[0::2]
        cap = merged.capacity
        cap_out = int(cap * route_capacity_factor / n_dev) + 8
        r, c, v, route_ovf = _route_entries(
            merged.rows, merged.cols, merged.vals, merged.valid_mask(),
            n_dev, cap_out,
        )
        # exchange: device d sends bucket j to device j
        if n_dev > 1:
            r = jax.lax.all_to_all(r, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
            c = jax.lax.all_to_all(c, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
            v = jax.lax.all_to_all(v, flat, split_axis=0, concat_axis=0,
                                   tiled=True)
        # owner-local dedup of everything received (rows all in my block)
        r, c, v = r.reshape(-1), c.reshape(-1), v.reshape(-1)
        n_valid = (r != SENTINEL).sum().astype(jnp.int32)
        # move sentinels to the back for the build contract
        order = jnp.argsort(r == SENTINEL, stable=True)
        mine = matrix_build(r[order], c[order], v[order],
                            n_valid=n_valid, dtype=v.dtype)
        local = analytics.window_stats(mine)
        out = {
            # row-keyed stats are exact under row ownership
            "valid_packets": jax.lax.psum(local["valid_packets"], axes),
            "unique_links": jax.lax.psum(mine.nnz, axes),
            "unique_sources": jax.lax.psum(local["unique_sources"], axes),
            "max_packets_per_link": jax.lax.pmax(
                local["max_packets_per_link"], axes),
            "max_source_packets": jax.lax.pmax(
                local["max_source_packets"], axes),
            "max_source_fanout": jax.lax.pmax(
                local["max_source_fanout"], axes),
            "src_packet_hist": jax.lax.psum(local["src_packet_hist"], axes),
            "src_fanout_hist": jax.lax.psum(local["src_fanout_hist"], axes),
            "merge_overflow": jax.lax.psum(ovf + route_ovf, axes),
        }
        return out

    return jax.shard_map(shard_fn, mesh=mesh, in_specs=P(flat),
                         out_specs=P(), check_vma=False)


# ---------------------------------------------------------------------------
# host driver (paper modes)
# ---------------------------------------------------------------------------
def run_paper_mode(mode: str, *, window_log2: int = 17,
                   windows_per_batch: int = 64, n_batches: int = 8,
                   anonymization: str = "feistel", kind: str = "uniform",
                   use_kernel: bool = False):
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization)

    @jax.jit
    def process(batch):
        merged, _, ovf = process_batch(batch, cfg)
        return {"nnz": merged.nnz, "overflow": ovf,
                "packets": analytics.window_stats(merged)["valid_packets"]}

    src = traffic_batches(
        seed=0, n_batches=n_batches + 1,
        windows_per_batch=windows_per_batch,
        window_size=cfg.window_size, kind=kind,
    )
    ppi = windows_per_batch * cfg.window_size
    if mode == "stream":
        rep = stream.run_stream(src, process, packets_per_item=ppi,
                                warmup_items=1)
    else:
        rep = stream.run_blocking(src, process, packets_per_item=ppi,
                                  warmup_items=1)
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="blocking",
                    choices=["blocking", "stream", "distributed"])
    ap.add_argument("--window-log2", type=int, default=17)
    ap.add_argument("--windows-per-batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--traffic", default="uniform",
                    choices=["uniform", "zipf"])
    ap.add_argument("--anonymization", default="feistel",
                    choices=["feistel", "cryptopan", "none"])
    args = ap.parse_args(argv)

    if args.mode == "distributed":
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        cfg = WindowConfig(window_log2=args.window_log2,
                           windows_per_batch=args.windows_per_batch,
                           anonymization=args.anonymization)
        step = make_exact_ingest_step(mesh, cfg)
        rng = np.random.default_rng(0)
        w = rng.integers(
            0, 1 << 32,
            (mesh.size * 2, cfg.window_size, 2), dtype=np.uint32,
        )
        t0 = time.time()
        out = jax.block_until_ready(step(jnp.asarray(w)))
        dt = time.time() - t0
        pkts = w.shape[0] * w.shape[1]
        print(f"[ingest/distributed] {pkts:,} packets in {dt:.2f}s "
              f"({pkts/dt:,.0f} pkt/s incl. compile)")
        print({k: int(v) for k, v in out.items() if v.ndim == 0})
        return out

    rep = run_paper_mode(
        args.mode, window_log2=args.window_log2,
        windows_per_batch=args.windows_per_batch, n_batches=args.batches,
        anonymization=args.anonymization, kind=args.traffic,
    )
    label = "GraphBLAS+IO" if args.mode == "stream" else "GraphBLAS only"
    print(f"[ingest/{label}] {rep.packets:,} packets, "
          f"{rep.elapsed_s:.2f}s -> {rep.packets_per_second:,.0f} pkt/s")
    return rep


if __name__ == "__main__":
    main()
