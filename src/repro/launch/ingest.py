"""Traffic-matrix ingest service: the paper's workload as a launcher.

A thin CLI over ``repro.engine.TrafficEngine`` (see DESIGN.md for the
Source -> Stage -> Sink architecture and the execution policies).  Three
modes, mapping 1:1 onto engine policies:

* ``--mode blocking``   — GraphBLAS-only (paper Fig. 2, red curve): pure
  build throughput over batches of windows.
* ``--mode stream``     — GraphBLAS+IO (paper Fig. 2, blue curve): producer
  thread materializes/transfers packets while the device builds the previous
  batch (double-buffered).
* ``--mode distributed``— the multi-pod path: windows shard across the mesh,
  per-device builds, and an EXACT global merge by row-block all_to_all
  (each device becomes the owner of a 2^32/n_dev slice of source-address
  space — the 2D decomposition from DESIGN.md). Exact distinct-source /
  distinct-link counts fall out because every (row) lives on exactly one
  owner.
* ``--mode async_pipelined`` / ``--mode sharded_pipelined`` — the async
  dispatch variants (DESIGN.md "Async dispatch & donation"): a ring of
  in-flight batches overlaps device->host readback with the next build;
  stats stay bit-identical to every other mode.

Workloads and sinks are independent axes:

* ``--source uniform|zipf|<capture.pcl>`` — the packet workload;
  ``--source flow|flow-zipf|<eve.json>`` — the Suricata-flow workload
  (value payloads accumulated with ``plus``; rates read as flows/s).
* ``--sink stats,anomaly,topk,pcap`` — comma list of streaming sinks;
  ``anomaly`` z-scores per-window fan-out histograms and reports flagged
  windows, ``pcap`` writes the anonymized stream back out for replay.
"""

from __future__ import annotations

import argparse

from repro.core.window import WindowConfig
from repro.engine import (
    AnomalySink,
    FaultPlan,
    FaultTolerance,
    PcapLiteWriterSink,
    ShardedPipelinedPolicy,
    ShardedPolicy,
    StatsAccumulator,
    TopKHeavyHitters,
    TrafficEngine,
    make_policy,
)
from repro.engine.source import SYNTHETIC_SPECS

# Re-exported for existing callers/tests; implementation lives in the engine.
from repro.engine.sharded import make_exact_ingest_step  # noqa: F401

# The paper's geometry for the packet workload; the flow workload defaults
# smaller (flow records are pre-aggregated, so real feeds are ~100x sparser
# than the packet stream — and the CLI must finish promptly on one core).
# Canonical home for per-workload defaults: configs/traffic_matrix.py's
# flow_window_config reads from here.
GEOMETRY_DEFAULTS = {
    "packets": dict(window_log2=17, windows_per_batch=64, n_batches=8),
    "flow": dict(window_log2=13, windows_per_batch=8, n_batches=4),
}


def infer_workload(source: str) -> str:
    s = str(source)
    if (s in ("flow", "flow-zipf", "device-flow", "device-flow-zipf")
            or s.endswith((".json", ".jsonl", ".eve"))):
        return "flow"
    return "packets"


def make_sinks(names, *, workload: str = "packets",
               pcap_out: str = "anonymized.pcl",
               anomaly_threshold: float = 3.0):
    """Resolve a comma list / sequence of sink names into Sink instances."""
    if isinstance(names, str):
        names = [n for n in names.split(",") if n]
    factories = {
        "stats": StatsAccumulator,
        "anomaly": lambda: AnomalySink(threshold=anomaly_threshold),
        "topk": lambda: TopKHeavyHitters(k=10),
        "pcap": lambda: PcapLiteWriterSink(
            path=pcap_out, key="flows" if workload == "flow" else "packets"
        ),
    }
    sinks = []
    for name in names:
        try:
            sinks.append(factories[name]())
        except KeyError:
            raise ValueError(
                f"unknown sink {name!r}; choose from {sorted(factories)}"
            ) from None
    return sinks


def run_paper_mode(mode: str, *, window_log2: int = 17,
                   windows_per_batch: int = 64, n_batches: int = 8,
                   anonymization: str = "feistel", kind: str = "uniform",
                   use_kernel: bool = False):
    """Run one Fig.-2 mode through the engine; returns its EngineReport.

    ``use_kernel=True`` routes the per-window builds through the fused
    Pallas kernel (``kernels/build_fused``) — stats are bit-identical.
    """
    cfg = WindowConfig(window_log2=window_log2,
                       windows_per_batch=windows_per_batch,
                       anonymization=anonymization,
                       build_kernel=use_kernel)
    policy = {"stream": "double_buffered", "blocking": "blocking"}.get(
        mode, mode
    )
    # Fig.-2 comparability: time build+merge only, like the paper.
    engine = TrafficEngine(cfg, policy=policy,
                           stages=("anonymize", "build", "merge"),
                           outputs=("merge_overflow",))
    # one extra leading batch absorbs jit compile (excluded from timing)
    return engine.run(kind, n_batches=n_batches + 1, seed=0, warmup_items=1)


def run_distributed(mesh, *, window_log2: int = 17,
                    windows_per_batch: int | None = None,
                    n_batches: int = 1, anonymization: str = "feistel",
                    kind: str = "uniform", pipelined: bool = False):
    """The sharded policy on ``mesh``; windows_per_batch defaults to
    2 windows per device.  ``pipelined=True`` uses ``sharded_pipelined``
    (bounded-queue transfer + async-dispatch ring) instead of the inline
    transfer."""
    wpb = windows_per_batch or mesh.size * 2
    cfg = WindowConfig(window_log2=window_log2, windows_per_batch=wpb,
                       anonymization=anonymization)
    policy = (ShardedPipelinedPolicy(mesh) if pipelined
              else ShardedPolicy(mesh))
    engine = TrafficEngine(cfg, policy=policy, sinks=[StatsAccumulator()])
    report = engine.run(kind, n_batches=n_batches, seed=0)
    return report, engine.finalize()["stats"]


def run_sinks(source: str, sink_names, *, mode: str = "blocking",
              window_log2: int | None = None,
              windows_per_batch: int | None = None,
              n_batches: int | None = None,
              anonymization: str = "feistel",
              pcap_out: str = "anonymized.pcl",
              anomaly_threshold: float = 3.0, seed: int = 0,
              use_kernel: bool = False,
              producer_workers: int | None = None,
              submit_batches: int | None = None,
              inject_faults: str | FaultPlan | None = None,
              max_retries: int = 3, retry_backoff: float = 0.0,
              attempt_timeout: float | None = None,
              on_exhausted: str = "raise",
              validate_batches: bool = False,
              checkpoint_dir: str | None = None,
              checkpoint_every: int = 0, resume: bool = False):
    """Generic engine run: any source spec x sink list x policy.

    Geometry arguments left as None take the workload's defaults.
    ``producer_workers``/``submit_batches`` forward to the policy
    constructor (an error for policies without the knob).

    Fault tolerance (engine.faults): ``inject_faults`` is a FaultPlan or
    its ``parse`` spec string; the retry knobs shape the RetryingSource
    wrapper and ``validate_batches`` adds the shape/dtype validator with a
    quarantine dead-letter sink.  ``checkpoint_dir``/``checkpoint_every``
    write crash-consistent engine checkpoints; ``resume=True`` restores the
    latest one and fast-forwards the source (synthetic sources keep the
    same n_batches+1 stream as the crashed run, but warmup is 0 — the
    resume cursor already accounts for the crashed run's warmup batch).

    Returns (EngineReport, finalized sink results keyed by sink name).
    """
    workload = infer_workload(source)
    geom = GEOMETRY_DEFAULTS[workload]
    cfg = WindowConfig(
        window_log2=window_log2 or geom["window_log2"],
        windows_per_batch=windows_per_batch or geom["windows_per_batch"],
        anonymization=anonymization,
        build_kernel=use_kernel,
    )
    policy = make_policy(
        {"stream": "double_buffered", "distributed": "sharded"}.get(
            mode, mode
        ),
        producer_workers=producer_workers, submit_batches=submit_batches,
    )
    engine = TrafficEngine(
        cfg, workload=workload, policy=policy,
        sinks=make_sinks(sink_names, workload=workload, pcap_out=pcap_out,
                         anomaly_threshold=anomaly_threshold),
    )
    ft = None
    if (inject_faults or validate_batches or attempt_timeout
            or on_exhausted != "raise"):
        plan = (FaultPlan.parse(inject_faults)
                if isinstance(inject_faults, str) else inject_faults)
        ft = FaultTolerance(
            plan=plan, max_retries=max_retries, backoff_s=retry_backoff,
            attempt_timeout_s=attempt_timeout, on_exhausted=on_exhausted,
            validate=validate_batches,
        )
    manager = None
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager

        manager = CheckpointManager(checkpoint_dir)
    # For synthetic sources one extra leading batch absorbs jit compile
    # (excluded from timing and sinks); file replays must not lose their
    # first batch, so they just eat the compile in their timing.  A resumed
    # run re-declares the crashed run's stream (same n_batches+1) but must
    # not warm up: the checkpoint's stream cursor already covers the
    # crashed run's warmup item, and the engine rejects warmup-on-resume.
    synthetic = str(source) in SYNTHETIC_SPECS
    report = engine.run(
        source,
        n_batches=(n_batches or geom["n_batches"]) + (1 if synthetic else 0),
        seed=seed, warmup_items=1 if synthetic and not resume else 0,
        fault_tolerance=ft, checkpoint_every=checkpoint_every,
        checkpoint_manager=manager, resume=resume,
    )
    return report, engine.finalize()


def _print_sink_results(results: dict) -> None:
    for name, res in results.items():
        if name == "stats":
            scalars = {k: int(v) for k, v in res.items()
                       if getattr(v, "ndim", None) == 0 or
                       isinstance(v, int)}
            print(f"  stats: {scalars}")
        elif name == "anomaly":
            print(f"  anomaly: flagged windows {res['flagged']} of "
                  f"{res['windows']} (|z| >= {res['threshold']})")
        elif name == "pcap":
            print(f"  pcap: wrote {res['packets']:,} anonymized pairs -> "
                  f"{res['path']}")
        elif name == "top_k":
            print(f"  top_k: {res[:3]}")
        else:
            print(f"  {name}: {res}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="blocking",
                    choices=["blocking", "stream", "double_buffered",
                             "triple_buffered", "async_pipelined",
                             "distributed", "sharded",
                             "sharded_pipelined"])
    ap.add_argument("--window-log2", type=int, default=None)
    ap.add_argument("--windows-per-batch", type=int, default=None)
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--traffic", default="uniform",
                    choices=["uniform", "zipf"])
    ap.add_argument("--source", default=None,
                    help="uniform | zipf | flow | flow-zipf | capture.pcl "
                         "| eve.json | device-uniform | device-zipf | "
                         "device-flow | device-flow-zipf (device-* generate "
                         "on device inside jit: zero H2D copies; defaults "
                         "to --traffic)")
    ap.add_argument("--producer-workers", type=int, default=None,
                    help="prefetch worker threads for the buffered/async "
                         "policies (in-order delivery at any count)")
    ap.add_argument("--submit-batches", type=int, default=None,
                    help="source batches stacked per device dispatch for "
                         "the async policies (one vmapped stage-graph "
                         "call; per-batch outputs unchanged)")
    ap.add_argument("--sink", default=None,
                    help="comma list: stats,anomaly,topk,pcap "
                         "(default stats)")
    ap.add_argument("--pcap-out", default="anonymized.pcl")
    ap.add_argument("--anomaly-threshold", type=float, default=3.0,
                    help="|z| flag threshold; the max reachable |z| over N "
                         "windows is sqrt(N-1), so lower this for short "
                         "runs (e.g. 2.5 for 8 windows)")
    ap.add_argument("--anonymization", default="feistel",
                    choices=["feistel", "cryptopan", "none"])
    ap.add_argument("--build-kernel", action="store_true",
                    help="route window builds through the fused Pallas "
                         "build kernel (kernels/build_fused; interpret "
                         "mode on CPU hosts) — stats are bit-identical")
    ap.add_argument("--inject-faults", default=None, metavar="PLAN",
                    help="deterministic fault plan, e.g. "
                         "'transient:2@1,slow:0.05@3,crash@4' "
                         "(see engine.faults.FaultPlan.parse)")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="bounded retries per batch for transient/timeout "
                         "source faults")
    ap.add_argument("--retry-backoff", type=float, default=0.0,
                    help="base exponential-backoff sleep between retries "
                         "(seconds)")
    ap.add_argument("--attempt-timeout", type=float, default=None,
                    help="per-attempt source read timeout (seconds); "
                         "timeouts count as retriable faults")
    ap.add_argument("--on-exhausted", default="raise",
                    choices=["raise", "skip"],
                    help="after max retries: fail the run, or skip the "
                         "batch and account it as dropped")
    ap.add_argument("--validate-batches", action="store_true",
                    help="shape/dtype-validate every delivered batch; "
                         "failures go to the quarantine dead-letter sink")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for crash-consistent engine checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="write a checkpoint after every K-th measured "
                         "batch (requires --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from "
                         "--checkpoint-dir and continue the interrupted "
                         "run (cold-starts if none exists)")
    args = ap.parse_args(argv)

    if (args.checkpoint_every or args.resume) and not args.checkpoint_dir:
        ap.error("--checkpoint-every/--resume require --checkpoint-dir")

    source = args.source if args.source is not None else args.traffic
    workload = infer_workload(source)

    if (args.sink is not None or args.source is not None
            or args.producer_workers is not None
            or args.submit_batches is not None
            or args.inject_faults is not None or args.validate_batches
            or args.checkpoint_dir is not None):
        # the generic Source x Sink path: an explicit --source must never
        # fall through to the synthetic-only legacy paths (which would
        # silently replay uniform traffic instead of the requested source)
        rep, results = run_sinks(
            source, args.sink or "stats", mode=args.mode,
            window_log2=args.window_log2,
            windows_per_batch=args.windows_per_batch,
            n_batches=args.batches, anonymization=args.anonymization,
            pcap_out=args.pcap_out,
            anomaly_threshold=args.anomaly_threshold,
            use_kernel=args.build_kernel,
            producer_workers=args.producer_workers,
            submit_batches=args.submit_batches,
            inject_faults=args.inject_faults,
            max_retries=args.max_retries,
            retry_backoff=args.retry_backoff,
            attempt_timeout=args.attempt_timeout,
            on_exhausted=args.on_exhausted,
            validate_batches=args.validate_batches,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            resume=args.resume,
        )
        unit = "flows" if workload == "flow" else "pkts"
        print(f"[ingest/{workload}/{rep.policy}] {rep.packets:,} {unit}, "
              f"{rep.elapsed_s:.2f}s -> {rep.packets_per_second:,.0f} "
              f"{unit[:-1]}/s (overflow {rep.merge_overflow})")
        if (rep.faults_injected or rep.retries or rep.batches_quarantined
                or rep.packets_dropped or rep.sink_write_failures):
            print(f"  faults: injected {rep.faults_injected}, retries "
                  f"{rep.retries}, quarantined {rep.batches_quarantined}, "
                  f"dropped {rep.packets_dropped:,} {unit}, sink failures "
                  f"{rep.sink_write_failures}")
        if rep.checkpoints_written or rep.resumed_from:
            print(f"  checkpoints: {rep.checkpoints_written} written, "
                  f"resumed at batch {rep.resumed_from}")
        _print_sink_results(results)
        return rep

    if args.mode in ("distributed", "sharded", "sharded_pipelined"):
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        rep, totals = run_distributed(
            mesh, window_log2=args.window_log2 or 17,
            n_batches=args.batches or 8,
            anonymization=args.anonymization, kind=args.traffic,
            pipelined=args.mode == "sharded_pipelined",
        )
        print(f"[ingest/distributed] {rep.summary()} (incl. compile)")
        print({k: int(v) for k, v in totals.items()
               if getattr(v, "ndim", 1) == 0 or isinstance(v, int)})
        return rep

    rep = run_paper_mode(
        args.mode, window_log2=args.window_log2 or 17,
        windows_per_batch=args.windows_per_batch or 64,
        n_batches=args.batches or 8,
        anonymization=args.anonymization, kind=args.traffic,
        use_kernel=args.build_kernel,
    )
    label = "GraphBLAS+IO" if args.mode != "blocking" else "GraphBLAS only"
    print(f"[ingest/{label}] {rep.packets:,} packets, "
          f"{rep.elapsed_s:.2f}s -> {rep.packets_per_second:,.0f} pkt/s")
    return rep


if __name__ == "__main__":
    main()
