"""Network analytics over hypersparse traffic matrices.

The standard quantities from the paper's analytic references (Trigg et al.,
"Hypersparse Network Flow Analysis of Packets with GraphBLAS", HPEC'22;
Jones et al. HPEC'22): per-window scalar statistics plus log-binned
distributions, all computed with GraphBLAS reductions so they run inside jit
on device, directly on the sorted-COO representation.

  valid packets            sum(A)
  unique links             nnz(A)
  unique sources           nnz of row reduction
  unique destinations      nnz of col reduction
  max packets per link     max(A)
  max source packets       max over row sums
  max source fan-out       max over row counts (out-degree)
  max dest packets         max over col sums
  max dest fan-in          max over col counts (in-degree)
  degree / packet histograms  log2-binned distributions
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops, types
from repro.core.hypersparse import HypersparseMatrix, HypersparseVector

HIST_BINS = 32  # log2 bins cover counts up to 2^31


def _log2_hist(vec: HypersparseVector, bins: int = HIST_BINS) -> jax.Array:
    """Histogram of floor(log2(value)) over the valid entries."""
    v = jnp.maximum(vec.vals, 1).astype(jnp.float32)
    b = jnp.clip(jnp.floor(jnp.log2(v)), 0, bins - 1).astype(jnp.int32)
    weights = vec.valid_mask().astype(jnp.int32)
    return jax.ops.segment_sum(weights, b, num_segments=bins)


def _max_valid(vec: HypersparseVector):
    masked = jnp.where(vec.valid_mask(), vec.vals, jnp.zeros_like(vec.vals))
    return masked.max()


def window_stats(A: HypersparseMatrix) -> dict[str, jax.Array]:
    """All standard analytics for one traffic matrix; jit/vmap friendly."""
    At = ops.transpose(A)
    ones = ops.apply(A, types.ONE)
    ones_t = ops.apply(At, types.ONE)

    src_packets = ops.reduce_rows(A, types.PLUS_MONOID)
    dst_packets = ops.reduce_rows(At, types.PLUS_MONOID)
    src_fanout = ops.reduce_rows(ones, types.PLUS_MONOID)
    dst_fanin = ops.reduce_rows(ones_t, types.PLUS_MONOID)

    return {
        "valid_packets": ops.reduce_scalar(A, types.PLUS_MONOID),
        "unique_links": A.nnz,
        "unique_sources": src_packets.nnz,
        "unique_destinations": dst_packets.nnz,
        "max_packets_per_link": ops.reduce_scalar(A, types.MAX_MONOID),
        "max_source_packets": _max_valid(src_packets),
        "max_source_fanout": _max_valid(src_fanout),
        "max_dest_packets": _max_valid(dst_packets),
        "max_dest_fanin": _max_valid(dst_fanin),
        "src_packet_hist": _log2_hist(src_packets),
        "dst_packet_hist": _log2_hist(dst_packets),
        "src_fanout_hist": _log2_hist(src_fanout),
        "dst_fanin_hist": _log2_hist(dst_fanin),
    }


def top_k_heavy_hitters(A: HypersparseMatrix, k: int):
    """Top-k links by packet count: (rows, cols, counts)."""
    vals = A.masked_vals()
    counts, idx = jax.lax.top_k(vals, k)
    return A.rows[idx], A.cols[idx], counts


def top_k_sources(A: HypersparseMatrix, k: int):
    """Top-k sources by outbound packets: (source_ids, counts)."""
    v = ops.reduce_rows(A, types.PLUS_MONOID)
    masked = jnp.where(v.valid_mask(), v.vals, jnp.zeros_like(v.vals))
    counts, idx = jax.lax.top_k(masked, k)
    return v.idx[idx], counts


window_stats_batched = jax.vmap(window_stats)


def src_fanout_hist(A: HypersparseMatrix) -> jax.Array:
    """Log2-binned source fan-out (out-degree) histogram of one matrix.

    The per-window feature the streaming anomaly detectors key on (Jones et
    al., "GraphBLAS on the Edge"): scans and sweeps shift mass into high
    fan-out bins that benign windows never populate.
    """
    ones = ops.apply(A, types.ONE)
    return _log2_hist(ops.reduce_rows(ones, types.PLUS_MONOID))


# [W, ...] window-matrix stack -> [W, HIST_BINS] per-window histograms.
src_fanout_hist_batched = jax.vmap(src_fanout_hist)
