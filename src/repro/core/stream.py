"""Compatibility shim: the streaming loops now live in ``repro.engine``.

``run_stream``/``run_blocking`` keep their signatures but delegate to the
engine's ``DoubleBufferedPolicy``/``BlockingPolicy`` — one implementation of
the producer/consumer loop instead of three hand-rolled copies.  New code
should use ``repro.engine.TrafficEngine`` directly.

Packet-rate accounting follows the single shared rule in
``repro.engine.telemetry.packets_in_item``: a buffer's trailing axis is the
(src, dst) coordinate pair and every leading axis indexes packets, so a
buffer counts ``prod(shape[:-1])`` packets (a ``[W, n, 2]`` batch is
``W * n``).  An explicit ``packets_per_item`` overrides inference.
"""

from __future__ import annotations

from typing import Callable, Iterable

from repro.engine.telemetry import (  # noqa: F401  (re-exports)
    EngineReport as StreamReport,
    packets_in_item,
)


def run_stream(
    source: Iterable,
    process_fn: Callable,
    *,
    queue_depth: int = 2,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
) -> StreamReport:
    """GraphBLAS+IO: double-buffered producer/consumer (Fig. 2, blue)."""
    from repro.engine.policies import DoubleBufferedPolicy

    return DoubleBufferedPolicy(queue_depth=queue_depth).run(
        source, process_fn,
        packets_per_item=packets_per_item, warmup_items=warmup_items,
    )


def run_blocking(
    source: Iterable,
    process_fn: Callable,
    *,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
) -> StreamReport:
    """GraphBLAS-only mode: no IO overlap; times pure build throughput."""
    from repro.engine.policies import BlockingPolicy

    return BlockingPolicy().run(
        source, process_fn,
        packets_per_item=packets_per_item, warmup_items=warmup_items,
    )
