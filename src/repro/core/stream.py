"""GraphBLAS+IO: the paper's producer/consumer streaming mode.

On the DPU, one thread receives packets from the wire while a second thread
builds hypersparse matrices from the previous window. The host-side analogue
here is a double-buffered pipeline: a producer thread materializes/transfers
the next window batch (the "IO" stage — on real hardware this is the NIC DMA
or the host->device transfer) while the device runs the jitted build+merge
step on the current one. JAX's async dispatch gives the overlap; an explicit
bounded queue gives backpressure exactly like the DPU's receive queues.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterable

import jax


@dataclasses.dataclass
class StreamReport:
    batches: int
    packets: int
    elapsed_s: float
    produce_s: float
    process_s: float
    results: list

    @property
    def packets_per_second(self) -> float:
        return self.packets / self.elapsed_s if self.elapsed_s > 0 else 0.0


_STOP = object()


def run_stream(
    source: Iterable,
    process_fn: Callable,
    *,
    queue_depth: int = 2,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
) -> StreamReport:
    """Run the GraphBLAS+IO pipeline.

    Args:
      source: iterable of host packet buffers (producer side; e.g. the
        pcap-lite reader or the synthetic generator).
      process_fn: jitted device function: buffer -> result pytree (the
        GraphBLAS build/merge/analytics step).
      queue_depth: receive-queue depth (2 = classic double buffering).
      packets_per_item: packets per buffer, for rate accounting; inferred
        from ``buf.shape[-3:-1]`` product if None and buffer is an array.
      warmup_items: leading items excluded from timing (jit compile).

    Returns a StreamReport with end-to-end packets/second — the paper's
    Figure-2 metric.
    """
    q: queue.Queue = queue.Queue(maxsize=queue_depth)
    produce_time = 0.0

    def producer():
        nonlocal produce_time
        for item in source:
            t0 = time.perf_counter()
            dev = jax.device_put(item)
            produce_time += time.perf_counter() - t0
            q.put(dev)
        q.put(_STOP)

    t = threading.Thread(target=producer, daemon=True)
    results = []
    n_items = 0
    n_packets = 0
    process_time = 0.0
    start = None

    t.start()
    while True:
        item = q.get()
        if item is _STOP:
            break
        if n_items == warmup_items:
            start = time.perf_counter()
        t0 = time.perf_counter()
        out = process_fn(item)
        out = jax.block_until_ready(out)
        process_time += time.perf_counter() - t0
        if n_items >= warmup_items:
            if packets_per_item is not None:
                n_packets += packets_per_item
            elif hasattr(item, "shape") and len(item.shape) >= 2:
                n = 1
                for d in item.shape[:-1]:
                    n *= d
                n_packets += n
            results.append(out)
        n_items += 1
    t.join()
    elapsed = (time.perf_counter() - start) if start is not None else 0.0

    return StreamReport(
        batches=max(n_items - warmup_items, 0),
        packets=n_packets,
        elapsed_s=elapsed,
        produce_s=produce_time,
        process_s=process_time,
        results=results,
    )


def run_blocking(
    source: Iterable,
    process_fn: Callable,
    *,
    packets_per_item: int | None = None,
    warmup_items: int = 0,
) -> StreamReport:
    """GraphBLAS-only mode: no IO overlap; times pure build throughput."""
    results = []
    n_items = 0
    n_packets = 0
    start = None
    for item in source:
        dev = jax.device_put(item)
        if n_items == warmup_items:
            start = time.perf_counter()
        out = jax.block_until_ready(process_fn(dev))
        if n_items >= warmup_items:
            results.append(out)
            if packets_per_item is not None:
                n_packets += packets_per_item
            elif hasattr(item, "shape") and len(item.shape) >= 2:
                n = 1
                for d in item.shape[:-1]:
                    n *= d
                n_packets += n
        n_items += 1
    elapsed = (time.perf_counter() - start) if start is not None else 0.0
    return StreamReport(
        batches=max(n_items - warmup_items, 0),
        packets=n_packets,
        elapsed_s=elapsed,
        produce_s=0.0,
        process_s=elapsed,
        results=results,
    )
