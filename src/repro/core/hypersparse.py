"""Hypersparse matrix container: the TPU-native stand-in for SuiteSparse's
hyper-CSC.

A traffic matrix over the full IPv4 space is 2^32 x 2^32 with only ~1e5
occupied entries per window, i.e. *hypersparse*: nnz << nrows.  SuiteSparse
stores these as hyper-CSC (a compressed list of non-empty columns).  JAX
requires static shapes, so we use the positional equivalent:

  * ``rows``/``cols``: ``uint32[capacity]`` coordinate lists,
  * ``vals``: ``dtype[capacity]`` values,
  * ``nnz``:  ``int32`` scalar — number of *valid* leading entries,

with the invariant that entries ``[0, nnz)`` are sorted lexicographically by
``(row, col)`` with no duplicate coordinates, and the tail ``[nnz, capacity)``
is padding.  Padding rows/cols hold ``SENTINEL = 0xFFFFFFFF`` so that padded
entries sort after real ones, but **masks derived from ``nnz`` are
authoritative** — ``(255.255.255.255 -> 255.255.255.255)`` is a legal packet
and must not be confused with padding.

``capacity`` (== rows.shape[0]) is a compile-time bound; all core ops carry
explicit output capacities and report overflow instead of silently dropping.

The container is registered as a pytree so it can flow through jit / vmap /
shard_map; ``nrows``/``ncols``/``shape`` are static metadata.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

SENTINEL = jnp.uint32(0xFFFFFFFF)
IPV4_SPACE = 1 << 32  # the paper's matrix dimension


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("rows", "cols", "vals", "nnz"),
    meta_fields=("nrows", "ncols"),
)
@dataclasses.dataclass
class HypersparseMatrix:
    """Sorted-COO hypersparse matrix with static capacity."""

    rows: jax.Array  # uint32[capacity]
    cols: jax.Array  # uint32[capacity]
    vals: jax.Array  # dtype[capacity]
    nnz: jax.Array  # int32 scalar
    nrows: int = IPV4_SPACE
    ncols: int = IPV4_SPACE

    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    @property
    def dtype(self):
        return self.vals.dtype

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrows, self.ncols)

    def valid_mask(self) -> jax.Array:
        """bool[capacity]: True for the leading ``nnz`` real entries."""
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.nnz

    def masked_vals(self, identity=0) -> jax.Array:
        """vals with padding replaced by ``identity`` (monoid-safe)."""
        ident = jnp.asarray(identity, dtype=self.vals.dtype)
        return jnp.where(self.valid_mask(), self.vals, ident)

    # -- conversion helpers (tests / small matrices only) -------------------

    def to_dense(self) -> jax.Array:
        """Densify. Only sensible for small nrows/ncols in tests."""
        if self.nrows * self.ncols > (1 << 24):
            raise ValueError(
                f"refusing to densify a {self.nrows}x{self.ncols} matrix"
            )
        dense = jnp.zeros((self.nrows, self.ncols), dtype=self.vals.dtype)
        r = jnp.minimum(self.rows, jnp.uint32(self.nrows - 1)).astype(jnp.int32)
        c = jnp.minimum(self.cols, jnp.uint32(self.ncols - 1)).astype(jnp.int32)
        v = self.masked_vals()
        return dense.at[r, c].add(v)

    def entries(self):
        """Host-side (rows, cols, vals) of valid entries (concrete only)."""
        n = int(self.nnz)
        return (
            jax.device_get(self.rows)[:n],
            jax.device_get(self.cols)[:n],
            jax.device_get(self.vals)[:n],
        )


def empty(
    capacity: int,
    dtype=jnp.int32,
    nrows: int = IPV4_SPACE,
    ncols: int = IPV4_SPACE,
) -> HypersparseMatrix:
    """An all-padding matrix of the given capacity."""
    return HypersparseMatrix(
        rows=jnp.full((capacity,), SENTINEL, dtype=jnp.uint32),
        cols=jnp.full((capacity,), SENTINEL, dtype=jnp.uint32),
        vals=jnp.zeros((capacity,), dtype=dtype),
        nnz=jnp.int32(0),
        nrows=nrows,
        ncols=ncols,
    )


def from_dense(dense, nrows=None, ncols=None) -> HypersparseMatrix:
    """Test helper: dense -> sorted-COO (capacity = size of dense)."""
    dense = jnp.asarray(dense)
    nr, nc = dense.shape
    rr, cc = jnp.meshgrid(
        jnp.arange(nr, dtype=jnp.uint32),
        jnp.arange(nc, dtype=jnp.uint32),
        indexing="ij",
    )
    flat_r, flat_c, flat_v = rr.ravel(), cc.ravel(), dense.ravel()
    present = flat_v != 0
    # stable partition: non-zeros first, preserving (row, col) order
    order = jnp.argsort(~present, stable=True)
    n = present.sum().astype(jnp.int32)
    slot = jnp.arange(flat_r.size, dtype=jnp.int32)
    rows = jnp.where(slot < n, flat_r[order], SENTINEL)
    cols = jnp.where(slot < n, flat_c[order], SENTINEL)
    vals = jnp.where(slot < n, flat_v[order], 0)
    return HypersparseMatrix(
        rows=rows.astype(jnp.uint32),
        cols=cols.astype(jnp.uint32),
        vals=vals.astype(dense.dtype),
        nnz=n,
        nrows=nrows or nr,
        ncols=ncols or nc,
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("idx", "vals", "nnz"),
    meta_fields=("length",),
)
@dataclasses.dataclass
class HypersparseVector:
    """Sorted sparse vector (result of row/col reductions)."""

    idx: jax.Array  # uint32[capacity]
    vals: jax.Array
    nnz: jax.Array  # int32 scalar
    length: int = IPV4_SPACE

    @property
    def capacity(self) -> int:
        return self.idx.shape[0]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.nnz

    def to_dense(self) -> jax.Array:
        if self.length > (1 << 24):
            raise ValueError("refusing to densify huge vector")
        out = jnp.zeros((self.length,), dtype=self.vals.dtype)
        i = jnp.minimum(self.idx, jnp.uint32(self.length - 1)).astype(jnp.int32)
        v = jnp.where(self.valid_mask(), self.vals, 0)
        return out.at[i].add(v)
