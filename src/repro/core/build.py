"""GrB_Matrix_build for TPU: sort + duplicate-accumulate, with static shapes.

This is the paper's hot loop.  SuiteSparse builds a hypersparse matrix from
(I, J, X) triples by sorting 64-bit packed keys and summing duplicates.  The
TPU-native equivalent implemented here:

  1. **lexicographic sort** of (row, col) with two stable 32-bit argsorts
     (col pass then row pass) — no 64-bit keys, x64 stays disabled;
  2. **run-boundary detection** on the sorted streams;
  3. **reduce-by-key** (segment sum/min/max) over the runs — on TPU this is
     the ``kernels/segsum`` Pallas kernel; the pure-jnp path here is also the
     oracle it is tested against;
  4. **compaction** of run heads into the output coordinate lists.

All steps are O(n log n) vector ops with static shapes, so the whole build
jits, vmaps across traffic windows, and shards across the data mesh axes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import types
from repro.core.hypersparse import (
    IPV4_SPACE,
    SENTINEL,
    HypersparseMatrix,
    HypersparseVector,
)

_SEGMENT_REDUCERS = {
    "plus": jax.ops.segment_sum,
    "times": jax.ops.segment_prod,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
    "lor": jax.ops.segment_max,
    "land": jax.ops.segment_min,
}


def lex_sort(rows, cols, *payloads, valid=None):
    """Sort entries lexicographically by (row, col).

    Two stable argsorts: sorting by ``col`` first, then stably by ``row``,
    yields (row, col) lexicographic order without 64-bit key packing.

    If ``valid`` is given (bool mask over entries, possibly interleaved —
    e.g. after concatenating two padded matrices), valid-before-invalid
    ordering within equal keys is folded into the *same* sort as a third
    key (one fused variadic ``lax.sort`` instead of the former 3-argsort
    pre-pass — the merge path's dominant cost), so that real entries whose
    key happens to equal ``SENTINEL`` (255.255.255.255 is a legal address)
    still land before padding and the "leading nnz are valid" invariant
    holds.  Both forms are stable, so their output order is identical.

    Returns (rows, cols, *payloads) permuted.
    """
    if valid is not None:
        invalid = (~valid).astype(jnp.uint32)
        out = jax.lax.sort(
            (rows, cols, invalid, *payloads), num_keys=3, is_stable=True
        )
        return (out[0], out[1], *out[3:])
    perm1 = jnp.argsort(cols, stable=True)
    perm2 = jnp.argsort(rows[perm1], stable=True)
    perm = perm1[perm2]
    return (rows[perm], cols[perm], *(p[perm] for p in payloads))


def _run_boundaries(rows, cols, valid):
    """flag[i] = 1 iff entry i starts a new (row, col) run among valid entries."""
    prev_r = jnp.concatenate([rows[:1], rows[:-1]])
    prev_c = jnp.concatenate([cols[:1], cols[:-1]])
    first = jnp.arange(rows.shape[0], dtype=jnp.int32) == 0
    new_key = (rows != prev_r) | (cols != prev_c) | first
    return new_key & valid


def dedup_sorted(
    rows,
    cols,
    vals,
    n_valid,
    dup: types.Monoid = types.PLUS_MONOID,
    *,
    use_kernel: bool = False,
):
    """Collapse duplicate coordinates in lexicographically sorted COO streams.

    Args:
      rows, cols: uint32[n] sorted by (row, col) among the leading ``n_valid``.
      vals: values aligned with rows/cols.
      n_valid: int32 scalar; entries at/after this index are padding.
      dup: duplicate-accumulation monoid (GrB dup op).
      use_kernel: route the reduce-by-key through the Pallas segsum kernel.

    Returns:
      (rows_out, cols_out, vals_out, nnz) with unique sorted coordinates in
      the leading ``nnz`` slots and sentinel padding after.
    """
    n = rows.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    valid = iota < n_valid

    flags = _run_boundaries(rows, cols, valid)
    # segment id for every input position; invalid entries go to segment n-1
    # with identity values so they cannot perturb any real segment.
    seg = jnp.cumsum(flags.astype(jnp.int32)) - 1
    seg = jnp.where(valid, jnp.maximum(seg, 0), n - 1)

    ident = dup.identity_for(vals.dtype)
    masked = jnp.where(valid, vals, ident)

    if use_kernel and dup.name == "plus":
        from repro.kernels.segsum import ops as segsum_ops

        out_vals = segsum_ops.segment_sum_sorted(masked, seg, num_segments=n)
    else:
        reducer = _SEGMENT_REDUCERS[dup.name]
        out_vals = reducer(masked, seg, num_segments=n)

    # first input index of each segment -> compact coordinates
    first_idx = jax.ops.segment_min(
        jnp.where(valid, iota, jnp.int32(n - 1)), seg, num_segments=n
    )
    first_idx = jnp.clip(first_idx, 0, n - 1)

    nnz = flags.sum().astype(jnp.int32)
    out_slot_valid = jnp.arange(n, dtype=jnp.int32) < nnz
    rows_out = jnp.where(out_slot_valid, rows[first_idx], SENTINEL)
    cols_out = jnp.where(out_slot_valid, cols[first_idx], SENTINEL)
    vals_out = jnp.where(out_slot_valid, out_vals, jnp.zeros_like(out_vals))
    return rows_out, cols_out, vals_out, nnz


def count_dedup_sorted(rows, cols, n_valid, dtype=jnp.int32):
    """Dedup for the counting build (all values = 1): run lengths come
    straight from the difference of consecutive run-head positions — no
    value payload is carried through the sort and no segment reduction
    runs at all. This is the traffic-matrix fast path (beyond-paper: the
    SuiteSparse build always reduces an explicit X array)."""
    n = rows.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    valid = iota < n_valid
    flags = _run_boundaries(rows, cols, valid)
    nnz = flags.sum().astype(jnp.int32)
    # compact the head positions: pos[p] = index of run p's first entry
    slot = jnp.where(flags, jnp.cumsum(flags.astype(jnp.int32)) - 1, n)
    first_idx = jnp.full((n,), n_valid, jnp.int32).at[slot].set(
        iota, mode="drop"
    )
    # next run's head (or n_valid for the last run)
    nxt = jnp.concatenate([first_idx[1:], jnp.full((1,), n_valid,
                                                   jnp.int32)])
    slot_valid = iota < nnz
    counts = jnp.where(slot_valid, nxt - first_idx, 0).astype(dtype)
    safe = jnp.clip(first_idx, 0, n - 1)
    rows_out = jnp.where(slot_valid, rows[safe], SENTINEL)
    cols_out = jnp.where(slot_valid, cols[safe], SENTINEL)
    return rows_out, cols_out, counts, nnz


def matrix_build(
    rows,
    cols,
    vals=None,
    *,
    nrows: int = IPV4_SPACE,
    ncols: int = IPV4_SPACE,
    dup: types.Monoid = types.PLUS_MONOID,
    n_valid=None,
    dtype=jnp.int32,
    use_kernel: bool = False,
    count_fast_path: bool = True,
) -> HypersparseMatrix:
    """GrB_Matrix_build: (I, J, X) triples -> hypersparse matrix.

    ``vals=None`` counts packets (X = 1), which is the traffic-matrix case;
    with ``count_fast_path`` that case skips the value payload entirely
    (run lengths are derived from run-head positions).
    Output capacity equals input length (worst case: all coordinates unique).

    ``use_kernel=True`` routes the whole sort + dedup-accumulate + compact
    through the fused Pallas kernel (``kernels/build_fused``) for the
    ``plus`` dup monoid — bit-identical to the jnp path below, which is its
    oracle.  Other monoids keep the jnp pipeline (where ``use_kernel``
    still routes the segment reduction through ``kernels/segsum``).
    """
    rows = rows.astype(jnp.uint32)
    cols = cols.astype(jnp.uint32)
    n = rows.shape[0]
    counting = vals is None
    if n_valid is None:
        n_valid = jnp.int32(n)
    else:
        n_valid = jnp.asarray(n_valid, dtype=jnp.int32)

    # Padding keys must sort last: force them to SENTINEL before sorting.
    iota = jnp.arange(n, dtype=jnp.int32)
    valid = iota < n_valid
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)

    if use_kernel and dup.name == "plus":
        from repro.kernels.build_fused import ops as fused_ops

        r, c, v, nnz = fused_ops.fused_build(
            rows, cols, None if counting else vals,
            n_valid=n_valid, dtype=dtype,
        )
        return HypersparseMatrix(
            rows=r, cols=c, vals=v, nnz=nnz, nrows=nrows, ncols=ncols
        )

    if counting and count_fast_path and dup.name == "plus":
        srows, scols = lex_sort(rows, cols)
        r, c, v, nnz = count_dedup_sorted(srows, scols, n_valid, dtype)
        return HypersparseMatrix(
            rows=r, cols=c, vals=v, nnz=nnz, nrows=nrows, ncols=ncols
        )

    if counting:
        vals = jnp.ones((n,), dtype=dtype)
    srows, scols, svals = lex_sort(rows, cols, vals)
    r, c, v, nnz = dedup_sorted(
        srows, scols, svals, n_valid, dup, use_kernel=use_kernel
    )
    return HypersparseMatrix(
        rows=r, cols=c, vals=v, nnz=nnz, nrows=nrows, ncols=ncols
    )


def build_window(
    packets,
    *,
    n_valid=None,
    dtype=jnp.int32,
    use_kernel: bool = False,
) -> HypersparseMatrix:
    """Build one traffic-window matrix from packets[(n, 2)] = (src, dst).

    This is exactly the paper's per-window unit of work (n = 2^17 there):
    A(src, dst) += 1 for every packet.
    """
    return matrix_build(
        packets[:, 0],
        packets[:, 1],
        None,
        dtype=dtype,
        n_valid=n_valid,
        use_kernel=use_kernel,
    )


# vmapped across a batch of windows: the paper's "64 windows per batch".
build_windows_batched = jax.vmap(
    partial(build_window), in_axes=0, out_axes=0
)


def build_flow_window(
    flows,
    *,
    value_col: int = 3,
    n_valid=None,
    dtype=jnp.int32,
    use_kernel: bool = False,
) -> HypersparseMatrix:
    """Build one traffic matrix from flow records [(n, >=4) uint32].

    The Suricata-flow variant of ``build_window`` (Houle et al.): columns 0/1
    are (src, dst) and ``value_col`` selects the payload (3 = packet counts,
    2 = byte counts), accumulated per link with the ``plus`` dup monoid:
    A(src, dst) += payload for every flow record.
    """
    return matrix_build(
        flows[:, 0],
        flows[:, 1],
        flows[:, value_col].astype(dtype),
        dtype=dtype,
        n_valid=n_valid,
        use_kernel=use_kernel,
    )


def vector_build(
    idx,
    vals,
    *,
    length: int = IPV4_SPACE,
    dup: types.Monoid = types.PLUS_MONOID,
    n_valid=None,
) -> HypersparseVector:
    """GrB_Vector_build via the same machinery (rows = 0)."""
    idx = idx.astype(jnp.uint32)
    n = idx.shape[0]
    if n_valid is None:
        n_valid = jnp.int32(n)
    zeros = jnp.zeros((n,), dtype=jnp.uint32)
    m = matrix_build(
        zeros, idx, vals, nrows=1, ncols=length, dup=dup, n_valid=n_valid,
        dtype=vals.dtype,
    )
    return HypersparseVector(idx=m.cols, vals=m.vals, nnz=m.nnz, length=length)
