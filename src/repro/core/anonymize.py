"""IP anonymization, vectorized over uint32 address arrays.

The paper constructs *anonymized* traffic matrices. Two schemes are provided,
both keyed and both pure JAX (fully vectorized, jit/vmap/shard_map friendly):

* ``feistel_permute`` — a 4-round balanced Feistel network over the 32-bit
  address space. A Feistel network is a bijection for any round function, so
  anonymized addresses never collide (distinct IPs stay distinct — required
  for traffic-matrix fidelity: nnz, fan-in/out etc. are preserved exactly).

* ``cryptopan`` — prefix-preserving anonymization in the style of CryptoPAn
  (Xu et al.): output bit i is input bit i XOR PRF(key, input[0:i]).  Two
  addresses sharing a k-bit prefix anonymize to addresses sharing exactly a
  k-bit prefix, so subnet structure survives anonymization. Also a bijection.

The round function / PRF is a strengthened xorshift-multiply integer hash
(splitmix-style avalanche), keyed per round. This is a measurement-fidelity
reproduction of the paper's anonymization stage, not a cryptographic claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_GOLDEN = jnp.uint32(0x9E3779B9)
_M1 = jnp.uint32(0x85EBCA6B)
_M2 = jnp.uint32(0xC2B2AE35)


def _avalanche(x: jax.Array) -> jax.Array:
    """murmur3-style 32-bit finalizer; x: uint32."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def derive_round_keys(key: int | jax.Array, rounds: int = 4) -> jax.Array:
    """Expand a user key into per-round uint32 subkeys."""
    k = jnp.uint32(key)
    ks = []
    for r in range(rounds):
        k = _avalanche(k + _GOLDEN * jnp.uint32(r + 1))
        ks.append(k)
    return jnp.stack(ks)


def feistel_permute(addr: jax.Array, key: int | jax.Array,
                    rounds: int = 4) -> jax.Array:
    """Keyed bijection over uint32 addresses (balanced 16/16 Feistel)."""
    addr = addr.astype(jnp.uint32)
    subkeys = derive_round_keys(key, rounds)
    left = addr >> 16
    right = addr & jnp.uint32(0xFFFF)

    def round_fn(i, lr):
        l, r = lr
        f = _avalanche(r ^ subkeys[i]) & jnp.uint32(0xFFFF)
        return (r, l ^ f)

    left, right = jax.lax.fori_loop(0, rounds, round_fn, (left, right))
    return (left << 16) | right


def feistel_unpermute(anon: jax.Array, key: int | jax.Array,
                      rounds: int = 4) -> jax.Array:
    """Inverse of ``feistel_permute`` (used to validate bijectivity)."""
    anon = anon.astype(jnp.uint32)
    subkeys = derive_round_keys(key, rounds)
    left = anon >> 16
    right = anon & jnp.uint32(0xFFFF)

    def round_fn(i, lr):
        l, r = lr
        rk = subkeys[rounds - 1 - i]
        f = _avalanche(l ^ rk) & jnp.uint32(0xFFFF)
        return (r ^ f, l)

    left, right = jax.lax.fori_loop(0, rounds, round_fn, (left, right))
    return (left << 16) | right


def cryptopan(addr: jax.Array, key: int | jax.Array) -> jax.Array:
    """Prefix-preserving anonymization: bit i flips by PRF of the i-prefix.

    out_bit[i] = in_bit[i] XOR f_key(in >> (32 - i)), processed MSB-first.
    Because the flip of bit i depends only on the more-significant input
    bits, equal k-prefixes map to equal k-prefixes (and the map is a
    bijection: invert by reconstructing the prefix MSB-first).
    """
    addr = addr.astype(jnp.uint32)
    k = _avalanche(jnp.uint32(key) ^ _GOLDEN)

    def bit_step(i, out):
        # prefix of the *input* above bit position (31 - i)
        shift = jnp.uint32(32 - i)
        # jnp >> 32 is undefined for uint32; fold i==0 into a where
        prefix = jnp.where(i == 0, jnp.uint32(0), addr >> jnp.minimum(shift, 31))
        prefix = jnp.where(shift >= 32, jnp.uint32(0), prefix)
        flip = _avalanche(prefix ^ k ^ (jnp.uint32(i) * _GOLDEN)) & jnp.uint32(1)
        bitpos = jnp.uint32(31 - i)
        return out ^ (flip << bitpos)

    return jax.lax.fori_loop(0, 32, bit_step, addr)


def cryptopan_inverse(anon: jax.Array, key: int | jax.Array) -> jax.Array:
    """Invert ``cryptopan`` by rebuilding the input prefix MSB-first."""
    anon = anon.astype(jnp.uint32)
    k = _avalanche(jnp.uint32(key) ^ _GOLDEN)

    def bit_step(i, recovered):
        shift = jnp.uint32(32 - i)
        prefix = jnp.where(
            i == 0, jnp.uint32(0), recovered >> jnp.minimum(shift, 31)
        )
        prefix = jnp.where(shift >= 32, jnp.uint32(0), prefix)
        flip = _avalanche(prefix ^ k ^ (jnp.uint32(i) * _GOLDEN)) & jnp.uint32(1)
        bitpos = jnp.uint32(31 - i)
        in_bit = ((anon >> bitpos) & jnp.uint32(1)) ^ flip
        return recovered | (in_bit << bitpos)

    return jax.lax.fori_loop(0, 32, bit_step, jnp.zeros_like(anon))


def anonymize_packets(packets: jax.Array, key: int | jax.Array,
                      scheme: str = "feistel") -> jax.Array:
    """Anonymize a packet array [(n, 2) uint32 = (src, dst)] in one pass."""
    if scheme == "feistel":
        return feistel_permute(packets, key)
    if scheme == "cryptopan":
        return cryptopan(packets, key)
    if scheme == "none":
        return packets
    raise ValueError(f"unknown anonymization scheme: {scheme}")
