"""GraphBLAS operations over hypersparse matrices.

Element-wise union/intersection merges, mxm (SpGEMM), reductions, apply /
select / extract, transpose, and the dense-RHS products (SpMM / SDDMM) that
the GNN and analytics layers are built on.

Everything keeps the sorted-COO + static-capacity discipline from
``hypersparse.py``: outputs carry explicit capacities, and operations that
can overflow a static capacity return an overflow count instead of silently
dropping entries.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import types
from repro.core.build import lex_sort, matrix_build
from repro.core.hypersparse import (
    SENTINEL,
    HypersparseMatrix,
    HypersparseVector,
)


# ---------------------------------------------------------------------------
# compaction helper (scatter-with-drop: positions >= out capacity fall away)
# ---------------------------------------------------------------------------
def _compact(flags, arrays, out_capacity, fills):
    """Scatter entries where ``flags`` into the leading slots of new arrays.

    Returns (compacted_arrays, n_selected, overflow). Entries beyond
    ``out_capacity`` are dropped and counted.
    """
    n = flags.shape[0]
    pos = jnp.cumsum(flags.astype(jnp.int32)) - 1
    # invalid or overflowing entries scatter to index out_capacity -> dropped
    tgt = jnp.where(flags & (pos < out_capacity), pos, out_capacity)
    outs = []
    for arr, fill in zip(arrays, fills):
        out = jnp.full((out_capacity,), fill, dtype=arr.dtype)
        outs.append(out.at[tgt].set(arr, mode="drop"))
    n_sel = flags.sum().astype(jnp.int32)
    overflow = jnp.maximum(n_sel - out_capacity, 0)
    return outs, jnp.minimum(n_sel, out_capacity), overflow


def with_capacity(A: HypersparseMatrix, capacity: int):
    """Shrink/grow the static capacity. Returns (matrix, overflow_count)."""
    flags = A.valid_mask()
    (r, c, v), nnz, ovf = _compact(
        flags,
        (A.rows, A.cols, A.vals),
        capacity,
        (SENTINEL, SENTINEL, jnp.zeros((), A.vals.dtype)),
    )
    return (
        HypersparseMatrix(rows=r, cols=c, vals=v, nnz=nnz,
                          nrows=A.nrows, ncols=A.ncols),
        ovf,
    )


# ---------------------------------------------------------------------------
# element-wise merges
# ---------------------------------------------------------------------------
class MergeResult(NamedTuple):
    matrix: HypersparseMatrix
    overflow: jax.Array  # int32; entries dropped due to static capacity


def ewise_add(
    A: HypersparseMatrix,
    B: HypersparseMatrix,
    op: types.BinaryOp = types.PLUS,
    *,
    out_capacity: int | None = None,
) -> MergeResult:
    """GrB_eWiseAdd: set-union merge; ``op`` combines where both present.

    This is the traffic-matrix *merge* primitive: window matrices are summed
    pairwise up the 64-window batch hierarchy.
    """
    cap = out_capacity or (A.capacity + B.capacity)
    rows = jnp.concatenate([A.rows, B.rows])
    cols = jnp.concatenate([A.cols, B.cols])
    vals = jnp.concatenate(
        [A.vals, B.vals.astype(A.vals.dtype)]
    )
    valid = jnp.concatenate([A.valid_mask(), B.valid_mask()])
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)

    srows, scols, svals, svalid = lex_sort(rows, cols, vals, valid, valid=valid)
    n = srows.shape[0]

    # each key run has <= 2 valid entries (one per operand, A's first by
    # stability); merge pairs then compact run heads.
    nxt_same = (
        (srows == jnp.roll(srows, -1))
        & (scols == jnp.roll(scols, -1))
        & jnp.roll(svalid, -1)
        & svalid
    )
    nxt_same = nxt_same.at[-1].set(False)
    merged = jnp.where(nxt_same, op(svals, jnp.roll(svals, -1)), svals)

    prev_same = jnp.concatenate(
        [jnp.zeros((1,), dtype=bool), nxt_same[:-1]]
    )
    heads = svalid & ~prev_same
    (r, c, v), nnz, ovf = _compact(
        heads,
        (srows, scols, merged),
        cap,
        (SENTINEL, SENTINEL, jnp.zeros((), merged.dtype)),
    )
    return MergeResult(
        HypersparseMatrix(rows=r, cols=c, vals=v, nnz=nnz,
                          nrows=A.nrows, ncols=A.ncols),
        ovf,
    )


def ewise_mult(
    A: HypersparseMatrix,
    B: HypersparseMatrix,
    op: types.BinaryOp = types.TIMES,
    *,
    out_capacity: int | None = None,
) -> MergeResult:
    """GrB_eWiseMult: set-intersection merge (keys present in both)."""
    cap = out_capacity or min(A.capacity, B.capacity)
    rows = jnp.concatenate([A.rows, B.rows])
    cols = jnp.concatenate([A.cols, B.cols])
    vals = jnp.concatenate([A.vals, B.vals.astype(A.vals.dtype)])
    valid = jnp.concatenate([A.valid_mask(), B.valid_mask()])
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)

    srows, scols, svals, svalid = lex_sort(rows, cols, vals, valid, valid=valid)

    nxt_same = (
        (srows == jnp.roll(srows, -1))
        & (scols == jnp.roll(scols, -1))
        & jnp.roll(svalid, -1)
        & svalid
    )
    nxt_same = nxt_same.at[-1].set(False)
    merged = jnp.where(nxt_same, op(svals, jnp.roll(svals, -1)), svals)
    # keep only run heads that have a partner (present in both operands)
    (r, c, v), nnz, ovf = _compact(
        nxt_same,
        (srows, scols, merged),
        cap,
        (SENTINEL, SENTINEL, jnp.zeros((), merged.dtype)),
    )
    return MergeResult(
        HypersparseMatrix(rows=r, cols=c, vals=v, nnz=nnz,
                          nrows=A.nrows, ncols=A.ncols),
        ovf,
    )


# ---------------------------------------------------------------------------
# apply / select / extract / transpose
# ---------------------------------------------------------------------------
def apply(A: HypersparseMatrix, op: types.UnaryOp) -> HypersparseMatrix:
    vals = jnp.where(A.valid_mask(), op(A.vals), jnp.zeros_like(A.vals))
    return HypersparseMatrix(rows=A.rows, cols=A.cols, vals=vals, nnz=A.nnz,
                             nrows=A.nrows, ncols=A.ncols)


def select(A: HypersparseMatrix, keep) -> HypersparseMatrix:
    """GrB_select: keep entries where ``keep(rows, cols, vals)`` is True."""
    flags = keep(A.rows, A.cols, A.vals) & A.valid_mask()
    (r, c, v), nnz, _ = _compact(
        flags,
        (A.rows, A.cols, A.vals),
        A.capacity,
        (SENTINEL, SENTINEL, jnp.zeros((), A.vals.dtype)),
    )
    return HypersparseMatrix(rows=r, cols=c, vals=v, nnz=nnz,
                             nrows=A.nrows, ncols=A.ncols)


def extract_block(
    A: HypersparseMatrix, r0, r1, c0, c1, *, out_capacity: int | None = None
) -> HypersparseMatrix:
    """Extract the sub-block [r0, r1) x [c0, c1), coordinates rebased.

    This is the 2D-decomposition primitive: the 2^32 ID space is carved into
    block tiles for sharded merge/analytics and for feeding the Pallas SpMM
    kernel tiles.
    """
    cap = out_capacity or A.capacity
    flags = (
        (A.rows >= r0) & (A.rows < r1) & (A.cols >= c0) & (A.cols < c1)
        & A.valid_mask()
    )
    (r, c, v), nnz, ovf = _compact(
        flags,
        (A.rows - jnp.uint32(r0), A.cols - jnp.uint32(c0), A.vals),
        cap,
        (SENTINEL, SENTINEL, jnp.zeros((), A.vals.dtype)),
    )
    del ovf  # cap >= A.capacity cannot overflow when default
    return HypersparseMatrix(
        rows=r, cols=c, vals=v, nnz=nnz,
        nrows=int(r1 - r0), ncols=int(c1 - c0),
    )


def transpose(A: HypersparseMatrix) -> HypersparseMatrix:
    rows, cols, vals = lex_sort(A.cols, A.rows, A.vals, valid=A.valid_mask())
    return HypersparseMatrix(rows=rows, cols=cols, vals=vals, nnz=A.nnz,
                             nrows=A.ncols, ncols=A.nrows)


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------
def reduce_scalar(A: HypersparseMatrix, monoid: types.Monoid = types.PLUS_MONOID):
    ident = monoid.identity_for(A.vals.dtype)
    masked = jnp.where(A.valid_mask(), A.vals, ident)
    if monoid.name == "plus":
        return masked.sum()
    if monoid.name == "min":
        return masked.min()
    if monoid.name == "max":
        return masked.max()
    if monoid.name in ("times", "land"):
        return masked.prod()
    if monoid.name == "lor":
        return masked.max()
    raise ValueError(f"unsupported monoid {monoid.name}")


def reduce_rows(
    A: HypersparseMatrix,
    monoid: types.Monoid = types.PLUS_MONOID,
    *,
    out_capacity: int | None = None,
) -> HypersparseVector:
    """Row-wise reduction -> sparse vector over occupied rows.

    With PLUS this is "packets per source"; over ``apply(A, ONE)`` it is the
    source fan-out — the two workhorse analytics of the paper's pipeline.
    """
    cap = out_capacity or A.capacity
    n = A.capacity
    valid = A.valid_mask()
    prev = jnp.concatenate([A.rows[:1], A.rows[:-1]])
    first = jnp.arange(n, dtype=jnp.int32) == 0
    heads = ((A.rows != prev) | first) & valid

    seg = jnp.cumsum(heads.astype(jnp.int32)) - 1
    seg = jnp.where(valid, jnp.maximum(seg, 0), n - 1)
    ident = monoid.identity_for(A.vals.dtype)
    masked = jnp.where(valid, A.vals, ident)
    from repro.core.build import _SEGMENT_REDUCERS

    red = _SEGMENT_REDUCERS[monoid.name](masked, seg, num_segments=n)

    nnz = heads.sum().astype(jnp.int32)
    slot_valid = jnp.arange(n, dtype=jnp.int32) < nnz
    # scatter head coordinates into compacted slots; gather reduced values
    pos = jnp.where(heads, jnp.cumsum(heads.astype(jnp.int32)) - 1, n)
    idx_out = jnp.full((cap,), SENTINEL, dtype=jnp.uint32)
    idx_out = idx_out.at[pos].set(A.rows, mode="drop")
    vals_out = jnp.where(
        slot_valid[:cap], red[:cap], jnp.zeros((), A.vals.dtype)
    )
    return HypersparseVector(
        idx=idx_out, vals=vals_out, nnz=jnp.minimum(nnz, cap), length=A.nrows
    )


def reduce_cols(
    A: HypersparseMatrix,
    monoid: types.Monoid = types.PLUS_MONOID,
    *,
    out_capacity: int | None = None,
) -> HypersparseVector:
    return reduce_rows(transpose(A), monoid, out_capacity=out_capacity)


# ---------------------------------------------------------------------------
# mxm (SpGEMM) and dense-RHS products
# ---------------------------------------------------------------------------
class MxmResult(NamedTuple):
    matrix: HypersparseMatrix
    overflow: jax.Array  # expansion entries dropped (int32)


def mxm(
    A: HypersparseMatrix,
    B: HypersparseMatrix,
    semiring: types.Semiring = types.PLUS_TIMES,
    *,
    expansion_capacity: int,
    out_capacity: int | None = None,
) -> MxmResult:
    """GrB_mxm, expansion-based SpGEMM: C = A (+.x) B.

    Every A entry (i, k, a) joins all B entries (k, j, b) via binary search
    on B's sorted row stream; the (static) ``expansion_capacity`` bounds the
    number of multiplies, and overflowing products are counted, not dropped
    silently.
    """
    cap_out = out_capacity or expansion_capacity
    nA = A.capacity
    b_nnz = B.nnz

    a_valid = A.valid_mask()
    a_keys = jnp.where(a_valid, A.cols, SENTINEL)
    lo = jnp.searchsorted(B.rows, a_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(B.rows, a_keys, side="right").astype(jnp.int32)
    lo = jnp.minimum(lo, b_nnz)
    hi = jnp.minimum(hi, b_nnz)
    counts = jnp.where(a_valid, hi - lo, 0)

    cum = jnp.cumsum(counts)  # inclusive
    total = cum[-1]
    offsets = cum - counts  # exclusive

    e = jnp.arange(expansion_capacity, dtype=jnp.int32)
    t = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    t = jnp.minimum(t, nA - 1)
    e_valid = e < jnp.minimum(total, expansion_capacity)
    b_idx = jnp.clip(lo[t] + (e - offsets[t]), 0, B.capacity - 1)

    rows_e = jnp.where(e_valid, A.rows[t], SENTINEL)
    cols_e = jnp.where(e_valid, B.cols[b_idx], SENTINEL)
    vals_e = semiring.mul(A.vals[t], B.vals[b_idx].astype(A.vals.dtype))
    ident = semiring.add.identity_for(vals_e.dtype)
    vals_e = jnp.where(e_valid, vals_e, ident)

    C = matrix_build(
        rows_e,
        cols_e,
        vals_e,
        nrows=A.nrows,
        ncols=B.ncols,
        dup=semiring.add,
        n_valid=jnp.minimum(total, expansion_capacity),
        dtype=vals_e.dtype,
    )
    C, ovf2 = with_capacity(C, cap_out)
    overflow = jnp.maximum(total - expansion_capacity, 0).astype(jnp.int32) + ovf2
    return MxmResult(C, overflow)


def spmm_dense(
    A: HypersparseMatrix,
    X: jax.Array,
    *,
    num_rows: int,
    use_kernel: bool = False,
) -> jax.Array:
    """C[i, :] = sum_j A(i, j) * X[j, :]  (plus_times over a dense RHS).

    The GNN aggregation primitive; ``num_rows`` is the dense output height
    (node count), which must be concrete.
    """
    if use_kernel:
        from repro.kernels.spmm_coo import ops as spmm_ops

        return spmm_ops.spmm_coo(
            A.rows, A.cols, A.vals, X, A.nnz, num_rows=num_rows
        )
    cols = jnp.minimum(A.cols, jnp.uint32(X.shape[0] - 1)).astype(jnp.int32)
    rows = jnp.minimum(A.rows, jnp.uint32(num_rows - 1)).astype(jnp.int32)
    vals = A.masked_vals().astype(X.dtype)
    contrib = vals[:, None] * X[cols]
    contrib = jnp.where(A.valid_mask()[:, None], contrib, 0)
    return jax.ops.segment_sum(contrib, rows, num_segments=num_rows)


def sddmm(
    rows: jax.Array,
    cols: jax.Array,
    U: jax.Array,
    V: jax.Array,
    n_valid=None,
    *,
    use_kernel: bool = False,
) -> jax.Array:
    """Sampled dense-dense: e_k = <U[rows_k, :], V[cols_k, :]>.

    GAT edge-score primitive. rows/cols are edge endpoints (uint32/int32).
    """
    if use_kernel:
        from repro.kernels.sddmm import ops as sddmm_ops

        return sddmm_ops.sddmm(rows, cols, U, V, n_valid)
    r = jnp.minimum(rows.astype(jnp.int32), U.shape[0] - 1)
    c = jnp.minimum(cols.astype(jnp.int32), V.shape[0] - 1)
    out = jnp.einsum("ed,ed->e", U[r], V[c])
    if n_valid is not None:
        out = jnp.where(
            jnp.arange(out.shape[0], dtype=jnp.int32) < n_valid, out, 0
        )
    return out
