"""GraphBLAS-in-JAX: hypersparse traffic-matrix construction (the paper's
core contribution) as a composable JAX module."""

from repro.core.hypersparse import (  # noqa: F401
    IPV4_SPACE,
    SENTINEL,
    HypersparseMatrix,
    HypersparseVector,
    empty,
    from_dense,
)
from repro.core.build import (  # noqa: F401
    build_window,
    build_windows_batched,
    lex_sort,
    matrix_build,
    vector_build,
)
from repro.core import analytics, anonymize, ops, stream, types, window  # noqa: F401
