"""GraphBLAS vocabulary: monoids, binary ops, semirings, unary ops.

This is the algebraic core of the GraphBLAS specification (Bulucs et al.,
"Design of the GraphBLAS API for C") reduced to what a JAX implementation
needs: a ``Monoid`` is an associative binary op with an identity element (used
for duplicate accumulation in ``matrix_build``, for ewise merges, and for
reductions); a ``Semiring`` pairs an additive monoid with a multiplicative
binary op (used by ``mxm`` / ``mxv``).

Everything here is a pure-python frozen dataclass holding jnp-traceable
callables, so semirings can be passed straight through ``jax.jit``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

Array = Any  # jax array; kept loose to avoid importing jaxtyping at runtime


@dataclasses.dataclass(frozen=True)
class BinaryOp:
    """A GrB_BinaryOp: elementwise z = f(x, y)."""

    name: str
    fn: Callable[[Array, Array], Array]

    def __call__(self, x: Array, y: Array) -> Array:
        return self.fn(x, y)


@dataclasses.dataclass(frozen=True)
class Monoid:
    """A GrB_Monoid: associative BinaryOp + identity.

    ``identity`` is a python scalar; it is cast to the operand dtype at use
    sites so one monoid serves all dtypes (as in SuiteSparse's generic
    monoids).
    """

    name: str
    op: BinaryOp
    identity: float | int

    def __call__(self, x: Array, y: Array) -> Array:
        return self.op(x, y)

    def identity_for(self, dtype) -> Array:
        dt = jnp.dtype(dtype)
        ident = self.identity
        if ident == -_INF and not jnp.issubdtype(dt, jnp.floating):
            return jnp.array(jnp.iinfo(dt).min, dtype=dt)
        if ident == _INF and not jnp.issubdtype(dt, jnp.floating):
            return jnp.array(jnp.iinfo(dt).max, dtype=dt)
        return jnp.array(ident, dtype=dt)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A GrB_Semiring: (add monoid, multiply op)."""

    name: str
    add: Monoid
    mul: BinaryOp


@dataclasses.dataclass(frozen=True)
class UnaryOp:
    """A GrB_UnaryOp: z = f(x)."""

    name: str
    fn: Callable[[Array], Array]

    def __call__(self, x: Array) -> Array:
        return self.fn(x)


_INF = float("inf")

# ---------------------------------------------------------------------------
# Binary ops
# ---------------------------------------------------------------------------
PLUS = BinaryOp("plus", lambda x, y: x + y)
TIMES = BinaryOp("times", lambda x, y: x * y)
MIN = BinaryOp("min", jnp.minimum)
MAX = BinaryOp("max", jnp.maximum)
FIRST = BinaryOp("first", lambda x, y: x)
SECOND = BinaryOp("second", lambda x, y: y)
PAIR = BinaryOp("pair", lambda x, y: jnp.ones_like(x))  # aka ONEB
LOR = BinaryOp("lor", lambda x, y: jnp.maximum(x, y))  # over {0,1}
LAND = BinaryOp("land", lambda x, y: x * y)  # over {0,1}

# ---------------------------------------------------------------------------
# Monoids
# ---------------------------------------------------------------------------
PLUS_MONOID = Monoid("plus", PLUS, 0)
TIMES_MONOID = Monoid("times", TIMES, 1)
MIN_MONOID = Monoid("min", MIN, _INF)
MAX_MONOID = Monoid("max", MAX, -_INF)
LOR_MONOID = Monoid("lor", LOR, 0)
LAND_MONOID = Monoid("land", LAND, 1)

# ---------------------------------------------------------------------------
# Semirings (the ones the traffic-matrix + GNN paths actually use)
# ---------------------------------------------------------------------------
PLUS_TIMES = Semiring("plus_times", PLUS_MONOID, TIMES)   # ordinary linear algebra
PLUS_PAIR = Semiring("plus_pair", PLUS_MONOID, PAIR)      # structural counting
PLUS_FIRST = Semiring("plus_first", PLUS_MONOID, FIRST)
PLUS_SECOND = Semiring("plus_second", PLUS_MONOID, SECOND)
MIN_PLUS = Semiring("min_plus", MIN_MONOID, PLUS)         # shortest paths
MAX_TIMES = Semiring("max_times", MAX_MONOID, TIMES)
LOR_LAND = Semiring("lor_land", LOR_MONOID, LAND)         # reachability

SEMIRINGS = {
    s.name: s
    for s in (PLUS_TIMES, PLUS_PAIR, PLUS_FIRST, PLUS_SECOND, MIN_PLUS,
              MAX_TIMES, LOR_LAND)
}
MONOIDS = {
    m.name: m
    for m in (PLUS_MONOID, TIMES_MONOID, MIN_MONOID, MAX_MONOID, LOR_MONOID,
              LAND_MONOID)
}

# ---------------------------------------------------------------------------
# Unary ops
# ---------------------------------------------------------------------------
IDENTITY = UnaryOp("identity", lambda x: x)
AINV = UnaryOp("ainv", lambda x: -x)
ONE = UnaryOp("one", jnp.ones_like)
ABS = UnaryOp("abs", jnp.abs)
LOG1P = UnaryOp("log1p", jnp.log1p)
