"""Traffic windows and the batch merge hierarchy.

The paper's pipeline: packets -> windows of 2^17 packets -> 64 windows per
batch -> 8 batches. Each window becomes one hypersparse matrix; windows merge
pairwise up a binary tree into batch matrices (GraphBLAS ``ewise_add`` with
``plus``), which is both how SuiteSparse pipelines do it (Kepner et al.,
"GraphBLAS on the Edge") and exactly the shape that shards: leaves are
embarrassingly parallel across devices, upper tree levels become collectives.

Capacities follow a schedule: level l capacity = min(cap0 * 2^l, cap_max);
overflow (entries dropped when a merged matrix exceeds its static capacity)
is accumulated and reported — real traffic reuses addresses heavily, so
cap_max ~ 4x window size loses nothing in practice, but we audit it.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import anonymize as anon
from repro.core import ops, types
from repro.core.build import build_flow_window, build_window
from repro.core.hypersparse import HypersparseMatrix

PAPER_WINDOW_LOG2 = 17  # 2^17 packets per window
PAPER_WINDOWS_PER_BATCH = 64
PAPER_BATCHES = 8


@dataclasses.dataclass(frozen=True)
class WindowConfig:
    window_log2: int = PAPER_WINDOW_LOG2
    windows_per_batch: int = PAPER_WINDOWS_PER_BATCH
    anonymization: str = "feistel"  # feistel | cryptopan | none
    anonymization_key: int = 0xC0FFEE
    cap_max_log2: int = 19  # merged-matrix capacity ceiling (2^19 = 4x window)
    val_dtype: str = "int32"
    # route window builds through the fused Pallas kernel
    # (kernels/build_fused); bit-identical to the jnp path by contract
    build_kernel: bool = False

    @property
    def window_size(self) -> int:
        return 1 << self.window_log2

    @property
    def cap_max(self) -> int:
        return 1 << self.cap_max_log2

    def level_capacity(self, level: int) -> int:
        return min(self.window_size << level, self.cap_max)


def process_window(packets: jax.Array, cfg: WindowConfig) -> HypersparseMatrix:
    """Anonymize one window [(n, 2) uint32] and build its traffic matrix."""
    pkts = anon.anonymize_packets(packets, cfg.anonymization_key,
                                  cfg.anonymization)
    return build_window(pkts, dtype=jnp.dtype(cfg.val_dtype),
                        use_kernel=cfg.build_kernel)


def process_windows_batched(packets: jax.Array,
                            cfg: WindowConfig) -> HypersparseMatrix:
    """vmap of ``process_window`` over a [W, n, 2] window batch."""
    return jax.vmap(lambda p: process_window(p, cfg))(packets)


def anonymize_flows(flows: jax.Array, cfg: WindowConfig) -> jax.Array:
    """Anonymize the address columns of flow records [..., (src, dst,
    *payloads)]; payload columns ride along untouched."""
    addrs = anon.anonymize_packets(flows[..., :2], cfg.anonymization_key,
                                   cfg.anonymization)
    return jnp.concatenate([addrs, flows[..., 2:]], axis=-1)


def build_flow_windows(flows: jax.Array, cfg: WindowConfig,
                       value_col: int = 3) -> HypersparseMatrix:
    """vmap of the value-carrying build over a [W, n, >=4] flow batch
    (``value_col`` 3 = packet counts, 2 = byte counts)."""
    dtype = jnp.dtype(cfg.val_dtype)
    return jax.vmap(
        lambda f: build_flow_window(f, value_col=value_col, dtype=dtype,
                                    use_kernel=cfg.build_kernel)
    )(flows)


def process_flow_batch(flows: jax.Array, cfg: WindowConfig):
    """Anonymize + build-with-values + merge one flow batch: the flow
    analogue of ``process_batch``, shared by the stage graph and the
    sharded policy's per-device step so the two paths cannot diverge.
    Returns (batch_matrix, merge_overflow); values are packet counts.
    """
    anonymized = anonymize_flows(flows, cfg)
    windows = build_flow_windows(anonymized, cfg)
    return merge_tree(windows, cfg)


def merge_tree(
    stack: HypersparseMatrix,
    cfg: WindowConfig,
    op: types.BinaryOp = types.PLUS,
):
    """Merge a [W, ...]-batched matrix stack pairwise to a single matrix.

    Returns (merged_matrix, total_overflow). W must be a power of two.
    """
    w = stack.rows.shape[0]
    assert w & (w - 1) == 0, f"window count {w} must be a power of two"
    overflow = jnp.int32(0)
    level = 1
    while w > 1:
        cap = cfg.level_capacity(level)
        left = jax.tree.map(lambda a: a[0::2], stack)
        right = jax.tree.map(lambda a: a[1::2], stack)
        if w == 2:
            l1 = jax.tree.map(lambda a: a[0], left)
            r1 = jax.tree.map(lambda a: a[0], right)
            merged, ovf = ops.ewise_add(l1, r1, op, out_capacity=cap)
            overflow = overflow + ovf
            return merged, overflow
        merged, ovf = jax.vmap(
            lambda a, b: ops.ewise_add(a, b, op, out_capacity=cap)
        )(left, right)
        overflow = overflow + ovf.sum()
        stack = merged
        w //= 2
        level += 1
    # w == 1 on entry
    return jax.tree.map(lambda a: a[0], stack), overflow


def process_batch(packets: jax.Array, cfg: WindowConfig):
    """Full per-batch pipeline: [W, n, 2] packets -> one batch matrix.

    This is the unit the paper times in GraphBLAS-only mode (per-window
    builds) plus the hierarchical merge from the follow-on pipeline papers.
    Returns (batch_matrix, window_matrices, merge_overflow).
    """
    windows = process_windows_batched(packets, cfg)
    merged, overflow = merge_tree(windows, cfg)
    return merged, windows, overflow


def window_slices(packets: jax.Array, cfg: WindowConfig) -> jax.Array:
    """Reshape a flat [N, 2] packet stream into [W, window, 2] windows."""
    n = cfg.window_size
    w = packets.shape[0] // n
    return packets[: w * n].reshape(w, n, 2)


def capacity_schedule(cfg: WindowConfig) -> Sequence[int]:
    levels = cfg.windows_per_batch.bit_length() - 1
    return [cfg.level_capacity(l) for l in range(1, levels + 1)]
