"""Graph containers, synthetic graph generators, and a real neighbor
sampler for minibatch GNN training.

JAX needs static shapes, so every graph is padded: edge arrays carry
``n_edges`` valid entries, node arrays ``n_nodes``. The neighbor sampler
produces fixed-fanout sampled subgraphs from a padded-CSR adjacency — the
``minibatch_lg`` shape's sampled-training path.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HostGraph:
    """Host-side padded graph (numpy; device transfer at the jit boundary)."""

    x: np.ndarray          # [N_pad, d] float32
    edge_src: np.ndarray   # [E_pad] int32
    edge_dst: np.ndarray   # [E_pad] int32
    n_nodes: int
    n_edges: int
    labels: np.ndarray | None = None       # [N_pad] int32
    label_mask: np.ndarray | None = None   # [N_pad] int32
    coords: np.ndarray | None = None       # [N_pad, 3] float32 (egnn)

    def batch_dict(self) -> dict:
        d = {
            "x": self.x,
            "edge_src": self.edge_src,
            "edge_dst": self.edge_dst,
            "n_nodes": np.int32(self.n_nodes),
            "n_edges": np.int32(self.n_edges),
        }
        if self.labels is not None:
            d["labels"] = self.labels
        if self.label_mask is not None:
            d["label_mask"] = self.label_mask
        if self.coords is not None:
            d["coords"] = self.coords
        return d


def _pad_to(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    if arr.shape[0] >= n:
        return arr[:n]
    pad_shape = (n - arr.shape[0],) + arr.shape[1:]
    return np.concatenate([arr, np.full(pad_shape, fill, arr.dtype)])


def symmetrize_with_self_loops(
    src: np.ndarray, dst: np.ndarray, n_nodes: int
) -> tuple[np.ndarray, np.ndarray]:
    """A := A + A^T + I (GCN convention), deduplicated."""
    s = np.concatenate([src, dst, np.arange(n_nodes, dtype=src.dtype)])
    d = np.concatenate([dst, src, np.arange(n_nodes, dtype=src.dtype)])
    key = s.astype(np.int64) * n_nodes + d
    _, idx = np.unique(key, return_index=True)
    return s[idx], d[idx]


def random_graph(
    seed: int,
    *,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int,
    pad_nodes: int | None = None,
    pad_edges: int | None = None,
    powerlaw: bool = True,
    with_coords: bool = False,
    symmetrize: bool = True,
) -> HostGraph:
    """Synthetic Cora/products-like graph with power-law degrees."""
    rng = np.random.default_rng(seed)
    if powerlaw:
        w = 1.0 / np.arange(1, n_nodes + 1) ** 0.8
        p = w / w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
        dst = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
        dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    if symmetrize:
        src, dst = symmetrize_with_self_loops(src, dst, n_nodes)
    pn = pad_nodes or n_nodes
    pe = pad_edges or len(src)
    n_real_edges = min(len(src), pe)
    return HostGraph(
        x=_pad_to(rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
                  pn),
        edge_src=_pad_to(src.astype(np.int32), pe),
        edge_dst=_pad_to(dst.astype(np.int32), pe),
        n_nodes=n_nodes,
        n_edges=n_real_edges,
        labels=_pad_to(rng.integers(0, n_classes, n_nodes).astype(np.int32),
                       pn),
        label_mask=_pad_to(
            (rng.random(n_nodes) < 0.1).astype(np.int32), pn
        ),
        coords=_pad_to(rng.standard_normal((n_nodes, 3)).astype(np.float32),
                       pn) if with_coords else None,
    )


def molecule_batch(
    seed: int,
    *,
    n_graphs: int,
    nodes_per_graph: int,
    edges_per_graph: int,
    d_feat: int,
    n_classes: int,
) -> dict:
    """Batch of small graphs flattened into one padded graph + graph_id."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per_graph
    E = n_graphs * edges_per_graph
    offs = np.repeat(
        np.arange(n_graphs, dtype=np.int32) * nodes_per_graph, edges_per_graph
    )
    src = rng.integers(0, nodes_per_graph, E).astype(np.int32) + offs
    dst = rng.integers(0, nodes_per_graph, E).astype(np.int32) + offs
    return {
        "x": rng.standard_normal((N, d_feat)).astype(np.float32),
        "coords": rng.standard_normal((N, 3)).astype(np.float32),
        "edge_src": src,
        "edge_dst": dst,
        "n_nodes": np.int32(N),
        "n_edges": np.int32(E),
        "graph_id": np.repeat(
            np.arange(n_graphs, dtype=np.int32), nodes_per_graph
        ),
        "graph_labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
        "labels": np.zeros((N,), np.int32),
        "label_mask": np.zeros((N,), np.int32),
    }


class PaddedCSR:
    """Fixed-max-degree CSR for O(1) uniform neighbor sampling."""

    def __init__(self, src: np.ndarray, dst: np.ndarray, n_nodes: int,
                 max_degree: int):
        order = np.argsort(dst, kind="stable")
        s, d = src[order], dst[order]
        self.n_nodes = n_nodes
        self.max_degree = max_degree
        self.neighbors = np.zeros((n_nodes, max_degree), np.int32)
        self.degrees = np.bincount(d, minlength=n_nodes).astype(np.int32)
        starts = np.concatenate([[0], np.cumsum(self.degrees)[:-1]])
        for v in range(n_nodes):
            deg = min(self.degrees[v], max_degree)
            self.neighbors[v, :deg] = s[starts[v] : starts[v] + deg]
        self.degrees = np.minimum(self.degrees, max_degree)


def sample_subgraph(
    csr: PaddedCSR,
    rng: np.random.Generator,
    batch_nodes: np.ndarray,
    fanouts: list[int],
) -> dict:
    """GraphSAGE-style layered uniform sampling.

    Returns a flattened subgraph: frontier-0 = batch nodes; layer l edges
    connect sampled neighbors (src) to layer-(l-1) nodes (dst), with LOCAL
    node ids into the concatenated node list.
    """
    nodes = [batch_nodes.astype(np.int32)]
    edges_src_local: list[np.ndarray] = []
    edges_dst_local: list[np.ndarray] = []
    offset = 0
    frontier = batch_nodes.astype(np.int32)
    for fanout in fanouts:
        deg = np.maximum(csr.degrees[frontier], 1)
        draw = rng.integers(0, 1 << 31, size=(len(frontier), fanout))
        picks = draw % deg[:, None]
        neigh = csr.neighbors[frontier[:, None],
                              picks.astype(np.int32)]  # [f, fanout]
        has_edge = (csr.degrees[frontier] > 0)[:, None]
        new_local_base = offset + len(frontier)
        src_local = (
            new_local_base
            + np.arange(neigh.size, dtype=np.int32).reshape(neigh.shape)
        )
        dst_local = np.broadcast_to(
            offset + np.arange(len(frontier), dtype=np.int32)[:, None],
            neigh.shape,
        )
        keep = np.broadcast_to(has_edge, neigh.shape).reshape(-1)
        edges_src_local.append(src_local.reshape(-1)[keep])
        edges_dst_local.append(dst_local.reshape(-1)[keep])
        nodes.append(neigh.reshape(-1))
        offset = new_local_base
        frontier = neigh.reshape(-1)
    all_nodes = np.concatenate(nodes)
    return {
        "node_ids": all_nodes,  # global ids, for feature gather
        "edge_src": np.concatenate(edges_src_local),
        "edge_dst": np.concatenate(edges_dst_local),
        "n_targets": len(batch_nodes),
    }
