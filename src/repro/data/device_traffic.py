"""Device-resident synthetic traffic: keyed window generation inside jit.

The host generators in ``data.packets``/``data.flows`` play the role of the
NIC: numpy materializes every batch on the host and the pipeline pays a
host->device copy per batch.  The paper's DPU never does that — packets
arrive *in* the device's receive queues — so these generators are the
faithful analogue: windows are generated on device by the jitted functions
below and never touch host memory (zero H2D copies on the produce path).

Keying scheme (the reproducibility contract):

* one base key per stream: ``stream_keys(seed)`` splits
  ``jax.random.key(seed)`` into a window key and a zipf-host-pool key;
* window ``w`` (the *global* window index, counted from the start of the
  stream) is generated from ``fold_in(window_key, w)``.

Because every window is keyed by its global index — not by threading RNG
state through the stream — the stream is a pure function of
``(seed, window_size, kind)``: re-batching the same stream with a different
``windows_per_batch`` yields bit-identical windows, any batch can be
regenerated in isolation, and N producer workers can generate windows out
of order without changing the stream.  That is what keeps device sources
inside the engine's policy-equivalence invariant.

Zipf ranks are drawn by inverting a CDF quantized to uint32
(``rank = searchsorted(cdf_u32, u32_draw)``) so the device computation is
pure integer compares — no float accumulation order to drift between
backends.  The table itself is computed once on the host in float64.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.flows import FLOW_WIDTH

# Mirrors data.packets.zipf_traffic / data.flows.synthetic_flows defaults.
N_HOSTS = 100_000
ZIPF_ALPHA = 1.2
MAX_PKTS = 64  # flow records: packets per flow in [1, MAX_PKTS]


def stream_keys(seed: int) -> tuple[jax.Array, jax.Array]:
    """(window_key, pool_key) for one stream.  ``threefry2x32`` is pinned so
    the stream survives a change of jax's default PRNG implementation."""
    base = jax.random.key(seed, impl="threefry2x32")
    window_key, pool_key = jax.random.split(base)
    return window_key, pool_key


@functools.lru_cache(maxsize=8)
def zipf_cdf_u32(n_hosts: int = N_HOSTS, alpha: float = ZIPF_ALPHA):
    """The rank CDF of a truncated zipf, quantized to uint32.

    ``searchsorted(cdf_u32, u)`` for a uniform uint32 draw ``u`` returns a
    rank in ``[0, n_hosts)`` with P(rank = k) proportional to (k+1)^-alpha
    — same law as the host generator's ``rng.zipf(alpha) % n_hosts`` up to
    truncation.  float64 happens here on the host, once; the device side
    only ever compares integers.
    """
    p = np.arange(1, n_hosts + 1, dtype=np.float64) ** -alpha
    cdf = np.cumsum(p / p.sum())
    return np.minimum(np.floor(cdf * (1 << 32)), (1 << 32) - 1).astype(
        np.uint32
    )


def zipf_hosts(pool_key: jax.Array, n_hosts: int = N_HOSTS) -> jax.Array:
    """The stream's host pool: [n_hosts] uint32 addresses, fixed per seed."""
    return jax.random.bits(pool_key, (n_hosts,), dtype=jnp.uint32)


def _window_keys(window_key: jax.Array, start_window: jax.Array,
                 windows_per_batch: int) -> jax.Array:
    ws = start_window + jnp.arange(windows_per_batch, dtype=jnp.uint32)
    return jax.vmap(lambda w: jax.random.fold_in(window_key, w))(ws)


def _zipf_pairs(key: jax.Array, hosts: jax.Array, cdf_u32: jax.Array,
                n: int) -> jax.Array:
    u = jax.random.bits(key, (n, 2), dtype=jnp.uint32)
    return hosts[jnp.searchsorted(cdf_u32, u)]


@functools.partial(jax.jit, static_argnames=("windows_per_batch",
                                             "window_size"))
def uniform_packet_batch(window_key, start_window, *,
                         windows_per_batch: int, window_size: int):
    """[W, n, 2] uint32 uniform packets for windows [start, start+W)."""
    keys = _window_keys(window_key, start_window, windows_per_batch)
    return jax.vmap(
        lambda k: jax.random.bits(k, (window_size, 2), dtype=jnp.uint32)
    )(keys)


@functools.partial(jax.jit, static_argnames=("windows_per_batch",
                                             "window_size"))
def zipf_packet_batch(window_key, start_window, hosts, cdf_u32, *,
                      windows_per_batch: int, window_size: int):
    """[W, n, 2] uint32 heavy-tailed packets over the stream's host pool."""
    keys = _window_keys(window_key, start_window, windows_per_batch)
    return jax.vmap(
        lambda k: _zipf_pairs(k, hosts, cdf_u32, window_size)
    )(keys)


def _flow_window(key, addrs):
    """Assemble one [n, 5] flow window from its address pairs + key."""
    n = addrs.shape[0]
    kp, kf, kg = jax.random.split(key, 3)
    pkts = jax.random.bits(kp, (n,), dtype=jnp.uint32) % MAX_PKTS + 1
    frame = jax.random.bits(kf, (n,), dtype=jnp.uint32) % 1461 + 40
    flags = jax.random.bits(kg, (n,), dtype=jnp.uint32) % 3 + 1
    return jnp.stack(
        [addrs[:, 0], addrs[:, 1], pkts * frame, pkts, flags], axis=1
    )


@functools.partial(jax.jit, static_argnames=("windows_per_batch",
                                             "window_size"))
def uniform_flow_batch(window_key, start_window, *,
                       windows_per_batch: int, window_size: int):
    """[W, n, 5] uint32 flow records (src, dst, bytes, pkts, flags)."""
    keys = _window_keys(window_key, start_window, windows_per_batch)

    def one(k):
        ka, kv = jax.random.split(k)
        addrs = jax.random.bits(ka, (window_size, 2), dtype=jnp.uint32)
        return _flow_window(kv, addrs)

    out = jax.vmap(one)(keys)
    assert out.shape[-1] == FLOW_WIDTH
    return out


@functools.partial(jax.jit, static_argnames=("windows_per_batch",
                                             "window_size"))
def zipf_flow_batch(window_key, start_window, hosts, cdf_u32, *,
                    windows_per_batch: int, window_size: int):
    """[W, n, 5] uint32 flow records with zipf-distributed addresses."""
    keys = _window_keys(window_key, start_window, windows_per_batch)

    def one(k):
        ka, kv = jax.random.split(k)
        addrs = _zipf_pairs(ka, hosts, cdf_u32, window_size)
        return _flow_window(kv, addrs)

    return jax.vmap(one)(keys)
