"""Data substrate: packet streams, token pipelines, graph containers."""
