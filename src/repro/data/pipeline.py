"""Host -> device input pipeline: prefetch, shard-aware placement.

``Prefetcher`` is a compatibility shim over
``repro.engine.prefetch.BoundedPrefetcher`` — the one bounded-queue
producer/consumer primitive shared with the ingest engine's
double-buffered execution policy.

``shard_batch`` places a host batch onto the mesh with the right
NamedSharding so jit steps consume it without implicit reshards.
"""

from __future__ import annotations

from typing import Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.engine.prefetch import BoundedPrefetcher


class Prefetcher(BoundedPrefetcher):
    """Background-thread prefetch of an iterator, depth-bounded."""


def batch_spec(batch: dict, mesh: Mesh, rules: dict[str, P]) -> dict:
    """PartitionSpec tree for a batch dict given per-key rules."""
    return {k: rules.get(k, P()) for k in batch}


def shard_batch(batch: dict, mesh: Mesh, rules: dict[str, P]) -> dict:
    """device_put a host batch with NamedShardings from ``rules``."""
    out = {}
    for k, v in batch.items():
        spec = rules.get(k, P())
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def prefetch_to_device(
    it: Iterable, mesh: Mesh, rules: dict[str, P], depth: int = 2
) -> Prefetcher:
    return Prefetcher(
        it, depth=depth, transform=lambda b: shard_batch(b, mesh, rules)
    )
