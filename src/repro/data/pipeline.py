"""Host -> device input pipeline: prefetch, shard-aware placement.

``Prefetcher`` overlaps host batch materialization + device transfer with
device compute (bounded queue, same double-buffer discipline as
``core.stream`` — the GraphBLAS+IO pattern generalized to all data kinds).

``shard_batch`` places a host batch onto the mesh with the right
NamedSharding so jit steps consume it without implicit reshards.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STOP = object()


class Prefetcher:
    """Background-thread prefetch of an iterator, depth-bounded."""

    def __init__(self, it: Iterable, depth: int = 2,
                 transform: Callable | None = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._transform = transform
        self._err: BaseException | None = None

        def worker():
            try:
                for item in it:
                    if self._transform is not None:
                        item = self._transform(item)
                    self._q.put(item)
            except BaseException as e:  # surface in consumer
                self._err = e
            finally:
                self._q.put(_STOP)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is _STOP:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def batch_spec(batch: dict, mesh: Mesh, rules: dict[str, P]) -> dict:
    """PartitionSpec tree for a batch dict given per-key rules."""
    return {k: rules.get(k, P()) for k in batch}


def shard_batch(batch: dict, mesh: Mesh, rules: dict[str, P]) -> dict:
    """device_put a host batch with NamedShardings from ``rules``."""
    out = {}
    for k, v in batch.items():
        spec = rules.get(k, P())
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def prefetch_to_device(
    it: Iterable, mesh: Mesh, rules: dict[str, P], depth: int = 2
) -> Prefetcher:
    return Prefetcher(
        it, depth=depth, transform=lambda b: shard_batch(b, mesh, rules)
    )
