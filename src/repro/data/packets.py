"""Synthetic network traffic + pcap-lite on-disk format.

The paper generates traffic two ways: replaying a PCAP (dpdk-burst-replay)
and wire-rate random 64-byte frames (pktgen). The analogues here:

* ``uniform_traffic`` — uniform random (src, dst) over the 2^32 space,
  matching the paper's "simulated random packets" (worst case for the
  builder: nearly all coordinates unique).
* ``zipf_traffic`` — heavy-tailed traffic over a host pool, matching real
  internet traffic (CAIDA-style), which exercises duplicate accumulation.
* ``PcapLite`` — a minimal binary capture format (magic + uint32 pairs,
  optionally zstd-compressed) so ingest can replay files like the DPU
  replays PCAPs.

Generation is numpy on the host (it plays the role of the NIC), so the
device pipeline's measured rate is pure GraphBLAS(+transfer) work.
"""

from __future__ import annotations

import dataclasses
import struct
from pathlib import Path
from typing import Iterator

import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover
    zstandard = None

MAGIC = b"PCAPLITE"
VERSION = 1


def uniform_traffic(rng: np.random.Generator, n: int) -> np.ndarray:
    """[n, 2] uint32 uniform random packets."""
    return rng.integers(0, 1 << 32, size=(n, 2), dtype=np.uint32)


def zipf_traffic(
    rng: np.random.Generator,
    n: int,
    *,
    n_hosts: int = 100_000,
    alpha: float = 1.2,
) -> np.ndarray:
    """[n, 2] uint32 heavy-tailed traffic over a random host pool."""
    hosts = rng.integers(0, 1 << 32, size=n_hosts, dtype=np.uint32)
    ranks_s = rng.zipf(alpha, size=n) % n_hosts
    ranks_d = rng.zipf(alpha, size=n) % n_hosts
    return np.stack([hosts[ranks_s], hosts[ranks_d]], axis=1)


@dataclasses.dataclass
class PcapLite:
    """Minimal packet capture: sequence of (src, dst) uint32 pairs."""

    @staticmethod
    def write(path: str | Path, packets: np.ndarray,
              compress: bool = True) -> None:
        packets = np.ascontiguousarray(packets.astype(np.uint32))
        raw = packets.tobytes()
        flags = 0
        if compress and zstandard is not None:
            raw = zstandard.ZstdCompressor(level=3).compress(raw)
            flags |= 1
        header = MAGIC + struct.pack("<HHQ", VERSION, flags, packets.shape[0])
        Path(path).write_bytes(header + raw)

    @staticmethod
    def read(path: str | Path) -> np.ndarray:
        blob = Path(path).read_bytes()
        assert blob[:8] == MAGIC, "not a pcap-lite file"
        version, flags, n = struct.unpack("<HHQ", blob[8:20])
        assert version == VERSION
        raw = blob[20:]
        if flags & 1:
            if zstandard is None:
                raise RuntimeError("zstandard required to read this capture")
            raw = zstandard.ZstdDecompressor().decompress(raw)
        return np.frombuffer(raw, dtype=np.uint32).reshape(n, 2).copy()

    @staticmethod
    def stream_windows(path: str | Path, window: int) -> Iterator[np.ndarray]:
        pkts = PcapLite.read(path)
        for i in range(0, len(pkts) - window + 1, window):
            yield pkts[i : i + window]


def traffic_batches(
    seed: int,
    *,
    n_batches: int,
    windows_per_batch: int,
    window_size: int,
    kind: str = "uniform",
) -> Iterator[np.ndarray]:
    """The paper's workload: batches of [W, window, 2] random packets."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        n = windows_per_batch * window_size
        if kind == "uniform":
            flat = uniform_traffic(rng, n)
        elif kind == "zipf":
            flat = zipf_traffic(rng, n)
        else:
            raise ValueError(kind)
        yield flat.reshape(windows_per_batch, window_size, 2)
