"""Flow records: Suricata-style network flows as fixed-width uint32 arrays.

The flow-analytics papers (Houle et al., "Hypersparse Traffic Matrices from
Suricata Network Flows using GraphBLAS") build the same traffic matrices this
repo builds from packets, but from *flow records*: one record per observed
flow carrying (src, dst) plus value payloads (byte and packet totals, state
flags).  The matrix entry A(src, dst) then accumulates the payload with the
``plus`` monoid instead of counting packets.

Host-side representation: ``uint32[n, 5]`` with columns

  0  src   — source address
  1  dst   — destination address
  2  bytes — bytes transferred (both directions)
  3  pkts  — packets transferred (both directions)
  4  flags — flow-state code (see FLOW_STATES)

Two interchange formats:

* synthetic generators (``synthetic_flows`` / ``flow_batches``) mirroring the
  packet generators in ``data.packets``;
* EVE-JSON-lite (``eve_write`` / ``eve_read``): one JSON object per line in
  the shape Suricata's eve.json uses for ``event_type: "flow"`` records —
  dotted-quad addresses, ``flow.bytes_toserver``/``flow.pkts_toserver`` etc.
  Only the fields the matrix pipeline needs are read; unknown lines and
  non-flow events are skipped, like a log tailer would.
"""

from __future__ import annotations

import ipaddress
import json
from pathlib import Path
from typing import Iterator

import numpy as np

FLOW_SRC, FLOW_DST, FLOW_BYTES, FLOW_PKTS, FLOW_FLAGS = range(5)
FLOW_WIDTH = 5

# Matrix values are int32 on device (x64 stays disabled), so per-record
# payloads are clamped to this at ingest; per-link *accumulation* beyond
# int32 still wraps — conservation is exact only within int32 range.
_VAL_MAX = 0x7FFFFFFF

# Suricata flow.state strings -> compact codes (column 4).
FLOW_STATES = {"new": 1, "established": 2, "closed": 3}
_STATE_NAMES = {v: k for k, v in FLOW_STATES.items()}


def ip_to_u32(s: str) -> int:
    """Dotted-quad (or integer string) -> uint32 host value."""
    return int(ipaddress.IPv4Address(s))


def u32_to_ip(v: int) -> str:
    return str(ipaddress.IPv4Address(int(v)))


def synthetic_flows(
    rng: np.random.Generator,
    n: int,
    *,
    kind: str = "uniform",
    n_hosts: int = 100_000,
    max_pkts: int = 64,
) -> np.ndarray:
    """[n, 5] uint32 flow records with byte/packet payloads.

    Addresses follow the packet generators (uniform over 2^32, or zipf over a
    host pool); packet counts are uniform in [1, max_pkts]; bytes are packets
    times a uniform per-packet size in [40, 1500] (min/max ethernet frame).
    """
    from repro.data.packets import uniform_traffic, zipf_traffic

    if kind == "uniform":
        addrs = uniform_traffic(rng, n)
    elif kind == "zipf":
        addrs = zipf_traffic(rng, n, n_hosts=n_hosts)
    else:
        raise ValueError(f"unknown flow kind: {kind!r}")
    pkts = rng.integers(1, max_pkts + 1, size=n, dtype=np.uint32)
    frame = rng.integers(40, 1501, size=n, dtype=np.uint32)
    flags = rng.integers(1, 4, size=n, dtype=np.uint32)
    out = np.empty((n, FLOW_WIDTH), dtype=np.uint32)
    out[:, FLOW_SRC] = addrs[:, 0]
    out[:, FLOW_DST] = addrs[:, 1]
    out[:, FLOW_BYTES] = pkts * frame
    out[:, FLOW_PKTS] = pkts
    out[:, FLOW_FLAGS] = flags
    return out


def flow_batches(
    seed: int,
    *,
    n_batches: int,
    windows_per_batch: int,
    window_size: int,
    kind: str = "uniform",
) -> Iterator[np.ndarray]:
    """Batches of [W, window, 5] flow records (the flow-path workload)."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        n = windows_per_batch * window_size
        flows = synthetic_flows(rng, n, kind=kind)
        yield flows.reshape(windows_per_batch, window_size, FLOW_WIDTH)


# -- EVE-JSON-lite ----------------------------------------------------------

def eve_write(path: str | Path, flows: np.ndarray) -> None:
    """Write [n, 5] flow records as EVE-JSON flow events (one per line)."""
    flows = np.asarray(flows, dtype=np.uint32).reshape(-1, FLOW_WIDTH)
    with open(path, "w") as f:
        for src, dst, nbytes, npkts, flags in flows.tolist():
            rec = {
                "event_type": "flow",
                "src_ip": u32_to_ip(src),
                "dest_ip": u32_to_ip(dst),
                "flow": {
                    # split like Suricata reports directions; the reader
                    # sums both, so any split round-trips the totals
                    "bytes_toserver": nbytes,
                    "bytes_toclient": 0,
                    "pkts_toserver": npkts,
                    "pkts_toclient": 0,
                    "state": _STATE_NAMES.get(flags, "new"),
                },
            }
            f.write(json.dumps(rec) + "\n")


def eve_read(path: str | Path) -> np.ndarray:
    """Parse EVE-JSON(-lite) flow events -> [n, 5] uint32 records.

    Non-flow events, blank lines, and malformed lines are skipped (an eve.json
    stream interleaves alerts/dns/etc. with flow records).
    """
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            if obj.get("event_type") != "flow":
                continue
            flow = obj.get("flow", {})
            try:
                src = ip_to_u32(obj["src_ip"])
                dst = ip_to_u32(obj["dest_ip"])
            except (KeyError, ipaddress.AddressValueError, ValueError):
                continue
            nbytes = int(flow.get("bytes_toserver", 0)) + int(
                flow.get("bytes_toclient", 0)
            )
            npkts = int(flow.get("pkts_toserver", 0)) + int(
                flow.get("pkts_toclient", 0)
            )
            flags = FLOW_STATES.get(flow.get("state", ""), 0)
            # Clamp payloads to the device value width (int32, x64 stays
            # disabled): a >2 GiB elephant flow saturates instead of
            # wrapping negative through the build's int32 values, and a
            # corrupt negative count floors at 0 instead of crashing the
            # uint32 conversion.
            nbytes = min(max(nbytes, 0), _VAL_MAX)
            npkts = min(max(npkts, 0), _VAL_MAX)
            out.append((src, dst, nbytes, npkts, flags))
    if not out:
        return np.zeros((0, FLOW_WIDTH), dtype=np.uint32)
    return np.asarray(out, dtype=np.uint32)
