"""Synthetic LM token pipeline with checkpointable iterator state.

Real deployments stream tokenized corpora; for a self-contained framework the
source is a seeded Zipf sampler over the vocab (heavy-tailed like natural
text). What matters for the system is the contract: deterministic,
shard-aware, and resumable — ``state()`` is saved in checkpoints and
``TokenStream.from_state`` resumes exactly, so restarts are bit-identical.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStreamState:
    seed: int
    step: int
    vocab_size: int
    batch: int
    seq_len: int


class TokenStream:
    """Deterministic batch iterator: step -> (tokens, labels)."""

    def __init__(self, seed: int, vocab_size: int, batch: int, seq_len: int,
                 step: int = 0):
        self._s = TokenStreamState(seed, step, vocab_size, batch, seq_len)

    @classmethod
    def from_state(cls, state: TokenStreamState | dict) -> "TokenStream":
        if isinstance(state, dict):
            state = TokenStreamState(**state)
        return cls(state.seed, state.vocab_size, state.batch, state.seq_len,
                   state.step)

    def state(self) -> dict:
        return dataclasses.asdict(self._s)

    def __iter__(self):
        return self

    def __next__(self):
        s = self._s
        # per-step independent generator => O(1) resume, no replay needed
        rng = np.random.default_rng((s.seed, s.step))
        z = rng.zipf(1.3, size=(s.batch, s.seq_len + 1))
        tokens = (z % s.vocab_size).astype(np.int32)
        self._s.step += 1
        return tokens[:, :-1], tokens[:, 1:]
