"""Pure-jnp oracle for EmbeddingBag.

JAX has no native nn.EmbeddingBag; the reference is the canonical
gather + segment-reduce construction over (bag_ids, indices, weights).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jax.Array,      # [vocab, dim]
    indices: jax.Array,    # int32[n] token/category ids
    bag_ids: jax.Array,    # int32[n] which bag each index belongs to
    num_bags: int,
    weights: jax.Array | None = None,
    n_valid=None,
    mode: str = "sum",
) -> jax.Array:
    n = indices.shape[0]
    valid = (
        jnp.arange(n, dtype=jnp.int32) < n_valid
        if n_valid is not None
        else jnp.ones((n,), dtype=bool)
    )
    idx = jnp.minimum(indices.astype(jnp.int32), table.shape[0] - 1)
    w = jnp.ones((n,), table.dtype) if weights is None else weights
    w = jnp.where(valid, w, 0)
    bags = jnp.where(valid, bag_ids.astype(jnp.int32), num_bags)
    gathered = table[idx] * w[:, None]
    summed = jax.ops.segment_sum(gathered, bags, num_segments=num_bags + 1)[
        :num_bags
    ]
    if mode == "sum":
        return summed
    if mode == "mean":
        counts = jax.ops.segment_sum(
            jnp.where(valid, 1.0, 0.0), bags, num_segments=num_bags + 1
        )[:num_bags]
        return summed / jnp.maximum(counts, 1.0)[:, None]
    raise ValueError(f"unsupported mode {mode}")
