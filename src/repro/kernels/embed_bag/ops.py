"""EmbeddingBag as hypersparse SpMM.

A bag lookup is exactly C = A @ T where A is the (bags x vocab) multi-hot
incidence matrix — i.e. GraphBLAS plus_times mxm with a hypersparse operand.
So the hot path reuses the spmm_coo Pallas kernel verbatim: rows = bag ids,
cols = category ids, vals = per-sample weights. One kernel, three users
(traffic matrices, GNN aggregation, recsys lookup).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm_coo import ops as spmm_ops


@functools.partial(
    jax.jit,
    static_argnames=("num_bags", "mode", "tile_r", "tile_c", "cap",
                     "interpret", "strict"),
)
def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    bag_ids: jax.Array,
    *,
    num_bags: int,
    weights: jax.Array | None = None,
    n_valid=None,
    mode: str = "sum",
    tile_r: int = spmm_ops.DEFAULT_TILE_R,
    tile_c: int = spmm_ops.DEFAULT_TILE_C,
    cap: int = spmm_ops.DEFAULT_CAP,
    interpret: bool | None = None,
    strict: bool = True,
) -> jax.Array:
    n = indices.shape[0]
    if n_valid is None:
        n_valid = jnp.int32(n)
    w = jnp.ones((n,), table.dtype) if weights is None else weights
    out = spmm_ops.spmm_coo(
        bag_ids, indices, w, table, n_valid,
        num_rows=num_bags, tile_r=tile_r, tile_c=tile_c, cap=cap,
        interpret=interpret, strict=strict,
    )
    if mode == "mean":
        valid = jnp.arange(n, dtype=jnp.int32) < n_valid
        counts = jax.ops.segment_sum(
            jnp.where(valid, 1.0, 0.0),
            jnp.minimum(bag_ids.astype(jnp.int32), num_bags - 1),
            num_segments=num_bags,
        )
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out
