"""Public wrappers for the segsum kernel (jit'd, CPU interpret fallback)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.segsum import kernel

_PAD_SEG = jnp.int32(0x7FFFFFFE)  # sorts after every real id; != close sentinel


def _pad(vals, seg, block_size):
    n = vals.shape[0]
    m = -(-n // block_size) * block_size
    if m == n:
        return vals, seg
    pad = m - n
    vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    seg = jnp.concatenate([seg, jnp.full((pad,), _PAD_SEG, seg.dtype)])
    return vals, seg


def _pick_block(n: int, block_size: int | None) -> int:
    if block_size is not None:
        return block_size
    if n <= kernel.DEFAULT_BLOCK:
        return max(128, -(-n // 128) * 128)
    return kernel.DEFAULT_BLOCK


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_size", "interpret")
)
def segment_sum_sorted(
    vals: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_size: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Segment sum for sorted ``seg`` via the Pallas run-total kernel.

    Drop-in for ``jax.ops.segment_sum`` under the sortedness precondition
    (which ``matrix_build`` guarantees). Out-of-range segment ids are
    dropped, matching the padding discipline of the build pipeline.
    """
    if interpret is None:
        interpret = default_interpret()
    seg = seg.astype(jnp.int32)
    bs = _pick_block(vals.shape[0], block_size)
    pvals, pseg = _pad(vals, seg, bs)
    totals = kernel.run_totals(pvals, pseg, block_size=bs, interpret=interpret)
    out = jnp.zeros((num_segments,), vals.dtype)
    return out.at[pseg].add(totals, mode="drop")


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def run_totals(
    vals: jax.Array,
    seg: jax.Array,
    *,
    block_size: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Position-space per-run totals (fused dedup fast path)."""
    if interpret is None:
        interpret = default_interpret()
    n = vals.shape[0]
    seg = seg.astype(jnp.int32)
    bs = _pick_block(n, block_size)
    pvals, pseg = _pad(vals, seg, bs)
    totals = kernel.run_totals(pvals, pseg, block_size=bs, interpret=interpret)
    return totals[:n]
