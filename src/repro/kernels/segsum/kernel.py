"""Sorted-run segment-sum Pallas kernel: the GrB build duplicate-accumulate
hot loop.

Problem: given values ``v[n]`` and *non-decreasing* segment ids ``seg[n]``
(the post-sort state inside ``matrix_build``), produce, at the CLOSING
position of every run, the total of that run (other positions get 0). The
wrapper then scatters the per-run totals wherever the caller needs them
(segment space for ``segment_sum_sorted``, or kept in position space for the
fused dedup path).

TPU-native formulation — no gathers, no scatters inside the kernel:

  * a **segmented inclusive scan** (``lax.associative_scan`` over
    (value, start-flag) pairs, log2(B) vector ops) gives the running
    within-run total at every position;
  * a run *closes* at position i iff ``seg[i] != seg[i+1]`` (the wrapper
    passes a globally shifted copy, so block boundaries need no peeking);
  * runs crossing block boundaries are handled with an SMEM **carry**
    (partial total + segment id of the open run), legal because TPU Pallas
    grids execute sequentially.

BlockSpec: 1D blocks of ``block_size`` elements (multiple of 128 lanes);
the value/seg/shifted-seg streams are tiled identically; output is tiled
the same so every grid step touches O(block) VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 2048  # 16 sublanes x 128 lanes of fp32


def _seg_scan(vals, starts):
    """Segmented inclusive scan: cumsum that restarts where starts=1."""

    def combine(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb, vb, va + vb), fa | fb

    total, _ = jax.lax.associative_scan(combine, (vals, starts))
    return total


def _segsum_kernel(seg_ref, nxt_ref, val_ref, out_ref, carry_val, carry_seg):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_val[0] = jnp.zeros((), val_ref.dtype)
        carry_seg[0] = jnp.int32(-1)

    seg = seg_ref[...]
    nxt = nxt_ref[...]
    val = val_ref[...]

    starts = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), seg[1:] != seg[:-1]]
    )
    running = _seg_scan(val, starts)

    # splice the carry into the first run if it continues the open run
    cont = seg == seg[0]
    carry_here = jnp.where(
        cont & (carry_seg[0] == seg[0]), carry_val[0], jnp.zeros((), val.dtype)
    )
    running = running + carry_here

    closes = seg != nxt
    out_ref[...] = jnp.where(closes, running, jnp.zeros((), val.dtype))

    # update carry: open iff the block's last run does not close at the end
    last_open = ~closes[-1]
    carry_val[0] = jnp.where(last_open, running[-1], jnp.zeros((), val.dtype))
    carry_seg[0] = jnp.where(last_open, seg[-1], jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def run_totals(
    vals: jax.Array,
    seg: jax.Array,
    *,
    block_size: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Per-run totals at closing positions (0 elsewhere). 1D, padded inputs.

    vals: [n] float/int values; seg: [n] int32 non-decreasing segment ids.
    n must be a multiple of ``block_size`` (wrapper pads: padding must use a
    segment id strictly greater than every real id, with value 0).
    """
    n = vals.shape[0]
    assert n % block_size == 0, (n, block_size)
    seg = seg.astype(jnp.int32)
    # seg of the next element; the final element always closes its run
    nxt = jnp.concatenate([seg[1:], jnp.full((1,), jnp.int32(0x7FFFFFFF))])

    grid = (n // block_size,)
    spec = pl.BlockSpec((block_size,), lambda i: (i,))
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), vals.dtype),
        scratch_shapes=[
            pltpu.SMEM((1,), vals.dtype),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(seg, nxt, vals)
