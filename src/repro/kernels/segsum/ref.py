"""Pure-jnp oracle for the segsum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def run_totals_ref(vals: jax.Array, seg: jax.Array) -> jax.Array:
    """Per-run totals at closing positions, 0 elsewhere.

    seg must be non-decreasing. Mirrors kernel semantics exactly.
    """
    seg = seg.astype(jnp.int32)
    n = vals.shape[0]
    totals = jax.ops.segment_sum(vals, seg, num_segments=n + 1)
    nxt = jnp.concatenate([seg[1:], jnp.full((1,), jnp.int32(0x7FFFFFFF))])
    closes = seg != nxt
    return jnp.where(closes, totals[jnp.clip(seg, 0, n)], jnp.zeros_like(vals))


def segment_sum_sorted_ref(
    vals: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """Plain sorted segment-sum into segment space (the dedup contract)."""
    return jax.ops.segment_sum(vals, seg.astype(jnp.int32),
                               num_segments=num_segments)
