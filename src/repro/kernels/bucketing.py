"""2D tile bucketing of COO edges — shared preprocessing for the spmm_coo
and sddmm kernels.

This is the TPU adaptation of the paper's hypersparse blocking: the (row,
col) ID space is carved into (TR x TC) tiles; every edge is routed to its
tile cell and given a slot inside the cell's fixed-capacity edge buffer.
Kernels then stream cells through VMEM with dense, MXU-aligned shapes.

The routing itself reuses the build machinery (a sort by cell id), so the
bucketing step is the same primitive the traffic-matrix builder runs — one
code path, two uses.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Buckets(NamedTuple):
    local_rows: jax.Array  # int32[RT*CT, cap] row % TR (0 for padding)
    local_cols: jax.Array  # int32[RT*CT, cap] col % TC (0 for padding)
    vals: jax.Array        # dtype[RT*CT, cap]  (0 for padding)
    cell_of_edge: jax.Array  # int32[n] cell id per original edge
    slot_of_edge: jax.Array  # int32[n] slot within cell (may exceed cap)
    overflow: jax.Array    # int32 scalar: edges that did not fit


def bucket_coo_2d(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    n_valid,
    *,
    num_rows: int,
    num_cols: int,
    tile_r: int,
    tile_c: int,
    cap: int,
) -> Buckets:
    """Route COO edges into (row-tile x col-tile) cells with ``cap`` slots."""
    n = rows.shape[0]
    rt = -(-num_rows // tile_r)
    ct = -(-num_cols // tile_c)
    n_cells = rt * ct

    r = jnp.minimum(rows.astype(jnp.int32), num_rows - 1)
    c = jnp.minimum(cols.astype(jnp.int32), num_cols - 1)
    valid = jnp.arange(n, dtype=jnp.int32) < n_valid

    cell = (r // tile_r) * ct + (c // tile_c)
    cell = jnp.where(valid, cell, n_cells)  # padding cell, dropped on scatter

    # slot within cell: rank among same-cell edges (stable by edge order)
    order = jnp.argsort(cell, stable=True)
    sorted_cell = cell[order]
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sorted_cell[1:] != sorted_cell[:-1]]
    )
    run_start = jax.lax.cummax(
        jnp.where(first, jnp.arange(n, dtype=jnp.int32), 0), axis=0
    )
    pos_in_run = jnp.arange(n, dtype=jnp.int32) - run_start
    slot = jnp.zeros((n,), jnp.int32).at[order].set(pos_in_run)

    in_cap = valid & (slot < cap)
    flat = jnp.where(in_cap, cell * cap + slot, n_cells * cap)

    def scatter(x, fill):
        buf = jnp.full((n_cells * cap,), fill, dtype=x.dtype)
        return buf.at[flat].set(x, mode="drop").reshape(n_cells, cap)

    lr = scatter(r % tile_r, jnp.int32(0))
    lc = scatter(c % tile_c, jnp.int32(0))
    zero = jnp.zeros((), vals.dtype)
    vv = scatter(jnp.where(in_cap, vals, zero), zero)

    overflow = (valid & (slot >= cap)).sum().astype(jnp.int32)
    return Buckets(lr, lc, vv, cell, slot, overflow)


def grid_shape(num_rows: int, num_cols: int, tile_r: int, tile_c: int):
    return (-(-num_rows // tile_r), -(-num_cols // tile_c))
