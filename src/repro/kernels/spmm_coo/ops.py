"""Public SpMM wrapper: bucket COO edges, run the Pallas kernel, fix up
capacity overflow exactly.

The bucketing capacity ``cap`` is a performance knob, not a correctness
bound: edges that overflow their cell are accumulated through the jnp
fallback path and added back in, so results are exact for any cap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.bucketing import bucket_coo_2d
from repro.kernels.spmm_coo import kernel
from repro.kernels.spmm_coo.ref import spmm_coo_ref

DEFAULT_TILE_R = 256
DEFAULT_TILE_C = 256
DEFAULT_CAP = 512


def _pad_axis(x, mult, axis, fill=0):
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_rows", "tile_r", "tile_c", "cap", "interpret", "strict"
    ),
)
def spmm_coo(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    n_valid,
    *,
    num_rows: int,
    tile_r: int = DEFAULT_TILE_R,
    tile_c: int = DEFAULT_TILE_C,
    cap: int = DEFAULT_CAP,
    interpret: bool | None = None,
    strict: bool = True,
) -> jax.Array:
    """C = A @ X for COO A (plus_times), fp32 out. See module docstring."""
    if interpret is None:
        interpret = default_interpret()
    num_cols = x.shape[0]
    tile_r = min(tile_r, max(8, num_rows))
    tile_c = min(tile_c, max(8, num_cols))

    b = bucket_coo_2d(
        rows, cols, vals, n_valid,
        num_rows=num_rows, num_cols=num_cols,
        tile_r=tile_r, tile_c=tile_c, cap=cap,
    )
    xp = _pad_axis(_pad_axis(x, tile_c, 0), 128, 1)
    out = kernel.spmm_bucketed(
        b.local_rows, b.local_cols, b.vals, xp,
        tile_r=tile_r, tile_c=tile_c, interpret=interpret,
    )
    out = out[:num_rows, : x.shape[1]]

    if strict:
        # exact overflow fix-up: re-run only overflowed edges via jnp path
        n = rows.shape[0]
        over = (b.slot_of_edge >= cap) & (
            jnp.arange(n, dtype=jnp.int32) < n_valid
        )
        zero = jnp.zeros((), vals.dtype)
        out = out + spmm_coo_ref(
            rows, cols, jnp.where(over, vals, zero), x, n_valid,
            num_rows=num_rows,
        )
    return out
