"""Pure-jnp oracle for the spmm_coo kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_coo_ref(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    n_valid,
    *,
    num_rows: int,
) -> jax.Array:
    """C[i, :] = sum_e [rows_e == i] * vals_e * X[cols_e, :], fp32."""
    n = rows.shape[0]
    valid = jnp.arange(n, dtype=jnp.int32) < n_valid
    r = jnp.minimum(rows.astype(jnp.int32), num_rows - 1)
    c = jnp.minimum(cols.astype(jnp.int32), x.shape[0] - 1)
    v = jnp.where(valid, vals, jnp.zeros((), vals.dtype)).astype(jnp.float32)
    contrib = v[:, None] * x[c].astype(jnp.float32)
    return jax.ops.segment_sum(contrib, r, num_segments=num_rows)
