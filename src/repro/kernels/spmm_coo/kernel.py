"""2D-blocked COO SpMM Pallas kernel.

C[i, :] += v_e * X[j, :] over edges e = (i, j, v), with edges pre-routed
into (row-tile x col-tile) cells (see ``kernels.bucketing``).

TPU-native mapping:
  * grid = (row_tiles, col_tiles); the col-tile axis is the contraction
    axis — output tiles are revisited and accumulated across it (sequential
    grid, so the accumulation is race-free);
  * the gather X[local_cols] reads rows of the VMEM-resident X col-tile
    (sublane gather);
  * the scatter-add into the output tile is expressed as a ONE-HOT MATMUL:
    onehot(local_rows)^T @ (v * X[local_cols]) — turning irregular
    scatter-add into dense MXU work, which is the whole point of blocking
    the hypersparse matrix;
  * all tile dims (TR, TC, cap, D) should be multiples of 8/128 for
    sublane/lane alignment; accumulation is fp32 regardless of X dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(lr_ref, lc_ref, v_ref, x_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lr = lr_ref[0]  # [cap] int32 local row ids
    lc = lc_ref[0]  # [cap] int32 local col ids
    v = v_ref[0]    # [cap] values (0 for padding)

    x = x_ref[...]  # [TC, D]
    gathered = jnp.take(x, lc, axis=0)  # [cap, D] sublane gather
    weighted = gathered * v[:, None].astype(x.dtype)

    tr = out_ref.shape[0]
    onehot = (
        lr[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, tr), 1)
    ).astype(x.dtype)  # [cap, TR]
    contrib = jax.lax.dot_general(
        onehot,
        weighted,
        (((0,), (0,)), ((), ())),  # contract over the edge axis
        preferred_element_type=jnp.float32,
    )  # [TR, D]
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile_r", "tile_c", "interpret"),
)
def spmm_bucketed(
    local_rows: jax.Array,  # int32[RT*CT, cap]
    local_cols: jax.Array,  # int32[RT*CT, cap]
    vals: jax.Array,        # [RT*CT, cap]
    x: jax.Array,           # [CT*TC, D]
    *,
    tile_r: int,
    tile_c: int,
    interpret: bool = False,
) -> jax.Array:
    """Run the kernel over pre-bucketed edges. Returns [RT*tile_r, D] fp32."""
    n_cells, cap = local_rows.shape
    ct = x.shape[0] // tile_c
    rt = n_cells // ct
    d = x.shape[1]

    cell_spec = pl.BlockSpec(
        (1, cap), lambda i, j, ct=ct: (i * ct + j, 0)
    )
    return pl.pallas_call(
        _spmm_kernel,
        grid=(rt, ct),
        in_specs=[
            cell_spec,
            cell_spec,
            cell_spec,
            pl.BlockSpec((tile_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rt * tile_r, d), jnp.float32),
        interpret=interpret,
    )(local_rows, local_cols, vals, x)
