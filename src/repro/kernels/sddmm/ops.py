"""Public SDDMM wrapper: bucket, kernel, un-bucket, exact overflow fix-up."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.bucketing import bucket_coo_2d
from repro.kernels.sddmm import kernel
from repro.kernels.sddmm.ref import sddmm_ref

DEFAULT_TILE_R = 256
DEFAULT_TILE_C = 256
DEFAULT_CAP = 512


def _pad_axis(x, mult, axis, fill=0):
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


@functools.partial(
    jax.jit,
    static_argnames=("tile_r", "tile_c", "cap", "interpret", "strict"),
)
def sddmm(
    rows: jax.Array,
    cols: jax.Array,
    u: jax.Array,
    v: jax.Array,
    n_valid=None,
    *,
    tile_r: int = DEFAULT_TILE_R,
    tile_c: int = DEFAULT_TILE_C,
    cap: int = DEFAULT_CAP,
    interpret: bool | None = None,
    strict: bool = True,
) -> jax.Array:
    """Edge scores in original edge order, fp32."""
    if interpret is None:
        interpret = default_interpret()
    n = rows.shape[0]
    if n_valid is None:
        n_valid = jnp.int32(n)
    num_rows, num_cols = u.shape[0], v.shape[0]
    tile_r = min(tile_r, max(8, num_rows))
    tile_c = min(tile_c, max(8, num_cols))

    ones = jnp.ones((n,), jnp.float32)
    b = bucket_coo_2d(
        rows, cols, ones, n_valid,
        num_rows=num_rows, num_cols=num_cols,
        tile_r=tile_r, tile_c=tile_c, cap=cap,
    )
    up = _pad_axis(_pad_axis(u, tile_r, 0), 128, 1)
    vp = _pad_axis(_pad_axis(v, tile_c, 0), 128, 1)
    scores = kernel.sddmm_bucketed(
        b.local_rows, b.local_cols, up, vp,
        tile_r=tile_r, tile_c=tile_c, interpret=interpret,
    )  # [n_cells, cap]

    in_cap = b.slot_of_edge < cap
    flat = jnp.where(
        in_cap,
        b.cell_of_edge * cap + jnp.minimum(b.slot_of_edge, cap - 1),
        0,
    )
    out = jnp.where(
        in_cap & (jnp.arange(n, dtype=jnp.int32) < n_valid),
        scores.reshape(-1)[jnp.clip(flat, 0, scores.size - 1)],
        0.0,
    )
    if strict:
        over = ~in_cap & (jnp.arange(n, dtype=jnp.int32) < n_valid)
        fallback = sddmm_ref(rows, cols, u, v, n_valid)
        out = jnp.where(over, fallback, out)
    return out
