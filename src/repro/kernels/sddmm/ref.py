"""Pure-jnp oracle for the sddmm kernel."""

from __future__ import annotations

import jax.numpy as jnp


def sddmm_ref(rows, cols, u, v, n_valid=None):
    """e_k = <U[rows_k], V[cols_k]>, fp32; invalid edges -> 0."""
    n = rows.shape[0]
    r = jnp.minimum(rows.astype(jnp.int32), u.shape[0] - 1)
    c = jnp.minimum(cols.astype(jnp.int32), v.shape[0] - 1)
    out = jnp.sum(
        u[r].astype(jnp.float32) * v[c].astype(jnp.float32), axis=1
    )
    if n_valid is not None:
        out = jnp.where(jnp.arange(n, dtype=jnp.int32) < n_valid, out, 0.0)
    return out
