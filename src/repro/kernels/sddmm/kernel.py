"""Blocked SDDMM Pallas kernel: per-edge dense-dense dots.

e_k = <U[r_k, :], V[c_k, :]> for edges routed into (row-tile x col-tile)
cells. The GAT edge-score primitive (and the masked-attention primitive in
GraphBLAS terms: (U V^T) .* pattern(A)).

Mapping: grid = (row_tiles, col_tiles); each step gathers the edge's U row
from the VMEM U row-tile and V row from the VMEM V col-tile (sublane
gathers), then reduces elementwise products over the lane (feature) axis —
pure VPU work with perfectly aligned tiles. Output is cell-major edge slots;
the wrapper scatters scores back to original edge order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(lr_ref, lc_ref, u_ref, v_ref, out_ref):
    lr = lr_ref[0]  # [cap] int32
    lc = lc_ref[0]  # [cap] int32
    u = u_ref[...]  # [TR, D]
    v = v_ref[...]  # [TC, D]
    ug = jnp.take(u, lr, axis=0)  # [cap, D]
    vg = jnp.take(v, lc, axis=0)  # [cap, D]
    out_ref[0] = jnp.sum(
        ug.astype(jnp.float32) * vg.astype(jnp.float32), axis=1
    )


@functools.partial(
    jax.jit, static_argnames=("tile_r", "tile_c", "interpret")
)
def sddmm_bucketed(
    local_rows: jax.Array,  # int32[RT*CT, cap]
    local_cols: jax.Array,  # int32[RT*CT, cap]
    u: jax.Array,           # [RT*TR, D]
    v: jax.Array,           # [CT*TC, D]
    *,
    tile_r: int,
    tile_c: int,
    interpret: bool = False,
) -> jax.Array:
    """Per-cell edge scores [RT*CT, cap] fp32."""
    n_cells, cap = local_rows.shape
    rt = u.shape[0] // tile_r
    ct = v.shape[0] // tile_c
    assert rt * ct == n_cells, (rt, ct, n_cells)
    d = u.shape[1]

    cell_spec = pl.BlockSpec((1, cap), lambda i, j, ct=ct: (i * ct + j, 0))
    return pl.pallas_call(
        _sddmm_kernel,
        grid=(rt, ct),
        in_specs=[
            cell_spec,
            cell_spec,
            pl.BlockSpec((tile_r, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=cell_spec,
        out_shape=jax.ShapeDtypeStruct((n_cells, cap), jnp.float32),
        interpret=interpret,
    )(local_rows, local_cols, u, v)
