"""Pallas TPU kernels for the compute hot-spots.

Each kernel lives in its own subpackage with three files:
  kernel.py — the pl.pallas_call + BlockSpec implementation (TPU target),
  ops.py    — the jit'd public wrapper (interpret=True on CPU hosts),
  ref.py    — the pure-jnp oracle the kernel is tested against.

Kernels:
  build_fused — the whole GrB_Matrix_build fused: single-block LSD radix
              sort over (row, col) byte digits + run dedup-accumulate +
              in-kernel head compaction with SMEM cursor/value carries.
  segsum    — sorted-run segment sum with cross-block carry: the
              GrB_Matrix_build duplicate-accumulation hot loop.
  spmm_coo  — 2D-blocked COO SpMM (scatter-add as one-hot MXU matmul):
              traffic-matrix x dense products and GNN aggregation.
  sddmm     — blocked sampled dense-dense dot (GAT edge scores).
  embed_bag — EmbeddingBag as plus_times SpMM (reuses spmm_coo): the
              recsys lookup hot path.
"""

import jax


def default_interpret() -> bool:
    """Pallas interpret mode unless running on a real TPU."""
    return jax.default_backend() != "tpu"
