"""Pure-jnp oracle for the fused build kernel.

The oracle *is* the existing `core.build` jnp pipeline (two stable argsorts
-> run boundaries -> segment reduce / run-length count -> gather compact);
the fused kernel must match it bit for bit, because a stable lexicographic
sort has a unique output and the plus reduction over int32 runs is
order-insensitive modulo 2^32 (and left-to-right for the kernel's scan,
which is the same association the oracle's segment_sum uses on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import types
from repro.core.build import count_dedup_sorted, dedup_sorted, lex_sort
from repro.core.hypersparse import SENTINEL


def fused_build_ref(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array | None = None,
    *,
    n_valid=None,
    dtype=jnp.int32,
    dup: types.Monoid = types.PLUS_MONOID,
):
    """(rows, cols, vals, nnz) exactly as `matrix_build`'s jnp path emits.

    vals=None is the counting build (run lengths, no payload through the
    sort). Padding keys are forced to SENTINEL before sorting so they land
    last; a *valid* entry whose key equals SENTINEL still precedes padding
    because validity is a prefix and the sorts are stable.
    """
    rows = rows.astype(jnp.uint32)
    cols = cols.astype(jnp.uint32)
    n = rows.shape[0]
    if n_valid is None:
        n_valid = jnp.int32(n)
    else:
        n_valid = jnp.asarray(n_valid, dtype=jnp.int32)
    valid = jnp.arange(n, dtype=jnp.int32) < n_valid
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)
    if vals is None:
        srows, scols = lex_sort(rows, cols)
        return count_dedup_sorted(srows, scols, n_valid, dtype)
    srows, scols, svals = lex_sort(rows, cols, vals)
    return dedup_sorted(srows, scols, svals, n_valid, dup)
