"""Fused GrB_Matrix_build Pallas kernels: radix sort + dedup + compact.

Two kernels cover the build hot loop (`core/build.py::matrix_build`):

**Radix sort** (`radix_sort_pairs`): an 8-pass LSD counting sort over the
(row, col) key pair treated as eight 8-bit digits — col bytes LSB->MSB then
row bytes LSB->MSB, which is exactly lexicographic (row, col) order without
ever packing a 64-bit key (x64 stays off).  Each pass is a 256-bin
histogram + exclusive prefix + stable in-bucket rank, all 32-bit vector
ops (VPU-friendly scans), replacing the two O(n log n) argsorts and their
materialized permutations.  Counting sort is stable, so the composition is
a *stable* lexicographic sort — bit-identical to the argsort oracle, since
a stable sort's output is uniquely determined.  Single-block (grid=(1,)):
the whole window must fit VMEM (2^17 keys x 6 streams = 3 MB, well inside
16 MB); the ops wrapper falls back to one variadic XLA sort when it does
not, or on CPU hosts where interpret-mode per-element loops lose to XLA.

**Dedup + compact** (`dedup_compact`): the rest of the build, fused into
one blocked pass over the *sorted* streams — run-boundary detection is done
by the wrapper as two O(n) compares (`starts`/`closes` streams, globally
shifted so blocks never peek across their edge, the `segsum` trick);
in-kernel a segmented inclusive scan accumulates the `plus` monoid within
runs, an SMEM value carry splices runs that straddle block boundaries
(legal: TPU grids execute sequentially), and every position that *closes* a
run scatters its (row, col, total) directly into the next free output slot
— an SMEM cursor carries the global run count, so compaction needs no
second pass and no materialized head-position array.  The counting fast
path is the same kernel with values synthesized as the validity mask (run
totals == run lengths), so no payload rides through the sort at all.

Output capacity equals input length (worst case all-unique), so the
compacted outputs keep static shapes; out-of-run positions stay at the
SENTINEL/zero fill written at grid step 0 (output blocks are full-array
resident and revisited, index_map i -> 0).  `nnz` is the final cursor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 8192  # dedup kernel: 64 sublanes x 128 lanes of u32

# hypersparse.SENTINEL as a Python literal: kernel bodies cannot close over
# traced module-level arrays, only embed scalar literals
_SENTINEL = 0xFFFFFFFF

# LSD digit schedule: (operand index, bit shift) — col bytes then row bytes,
# least significant first, so the final order is (row, col) lexicographic.
_DIGIT_SCHEDULE = (
    (1, 0), (1, 8), (1, 16), (1, 24),
    (0, 0), (0, 8), (0, 16), (0, 24),
)


def _counting_pass(digit, arrays):
    """One stable counting-sort pass: permute ``arrays`` by 8-bit ``digit``.

    Stable rank = bucket base (exclusive prefix of the 256-bin histogram)
    + within-bucket occurrence index (masked cumsum per bin).
    """
    n = digit.shape[0]
    hist = jnp.zeros((256,), jnp.int32).at[digit].add(jnp.int32(1))
    offs = jnp.cumsum(hist) - hist  # exclusive prefix: first slot per bucket

    def bin_body(b, pos):
        mask = digit == b
        within = jnp.cumsum(mask.astype(jnp.int32)) - jnp.int32(1)
        return jnp.where(mask, offs[b] + within, pos)

    pos = jax.lax.fori_loop(0, 256, bin_body, jnp.zeros((n,), jnp.int32))
    # pos is a permutation: forward scatter needs no drop handling
    return [jnp.zeros_like(a).at[pos].set(a) for a in arrays]


def _make_radix_kernel(n_payload: int):
    def kernel(*refs):
        arrays = [r[...] for r in refs[: 2 + n_payload]]
        for operand, shift in _DIGIT_SCHEDULE:
            key = arrays[operand]
            digit = (
                (key >> jnp.uint32(shift)) & jnp.uint32(0xFF)
            ).astype(jnp.int32)
            arrays = _counting_pass(digit, arrays)
        for out_ref, arr in zip(refs[2 + n_payload:], arrays):
            out_ref[...] = arr

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def radix_sort_pairs(rows, cols, *payloads, interpret: bool = False):
    """Stable lexicographic (row, col) sort; payload arrays ride along.

    rows/cols: uint32[n]. Single-block — n bounds VMEM; the ops wrapper
    gates on size and pads n to a lane multiple before calling.
    """
    n = rows.shape[0]
    operands = (rows, cols, *payloads)
    spec = pl.BlockSpec((n,), lambda i: (0,))
    outs = pl.pallas_call(
        _make_radix_kernel(len(payloads)),
        grid=(1,),
        in_specs=[spec] * len(operands),
        out_specs=[spec] * len(operands),
        out_shape=[jax.ShapeDtypeStruct((n,), a.dtype) for a in operands],
        interpret=interpret,
    )(*operands)
    return tuple(outs)


def _seg_scan(vals, starts):
    """Segmented inclusive scan: cumsum that restarts where starts=1."""

    def combine(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb, vb, va + vb), fa | fb

    total, _ = jax.lax.associative_scan(combine, (vals, starts))
    return total


def _dedup_compact_kernel(
    rows_ref, cols_ref, val_ref, starts_ref, closes_ref,
    rows_out, cols_out, vals_out, nnz_out,
    cursor, carry_val,
):
    i = pl.program_id(0)
    n_out = rows_out.shape[0]

    @pl.when(i == 0)
    def _init():
        cursor[0] = jnp.int32(0)
        carry_val[0] = jnp.zeros((), val_ref.dtype)
        rows_out[...] = jnp.full((n_out,), _SENTINEL, jnp.uint32)
        cols_out[...] = jnp.full((n_out,), _SENTINEL, jnp.uint32)
        vals_out[...] = jnp.zeros((n_out,), val_ref.dtype)

    r = rows_ref[...]
    c = cols_ref[...]
    v = val_ref[...]
    starts = starts_ref[...] != 0
    closes = closes_ref[...] != 0

    # within-run running totals; positions before the block's first run
    # start continue the previous block's open run -> splice the carry
    running = _seg_scan(v, starts)
    local_started = jnp.cumsum(starts.astype(jnp.int32)) > 0
    running = jnp.where(local_started, running, running + carry_val[0])

    # compacted destination of every closing position; non-closing
    # positions aim past the output and are dropped by the scatter
    emit_slot = jnp.cumsum(closes.astype(jnp.int32))
    dst = jnp.where(closes, cursor[0] + emit_slot - 1, jnp.int32(n_out))
    rows_out[...] = rows_out[...].at[dst].set(r, mode="drop")
    cols_out[...] = cols_out[...].at[dst].set(c, mode="drop")
    vals_out[...] = vals_out[...].at[dst].set(running, mode="drop")

    cursor[0] = cursor[0] + emit_slot[-1]
    carry_val[0] = jnp.where(
        closes[-1], jnp.zeros((), v.dtype), running[-1]
    )
    nnz_out[0] = cursor[0]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def dedup_compact(
    rows, cols, vals, starts, closes,
    *,
    block_size: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Fused duplicate-accumulate + head compaction over sorted streams.

    rows/cols: uint32[n] lexicographically sorted (padding at SENTINEL);
    vals: monoid values, already masked to 0 outside the valid prefix;
    starts/closes: int32[n] run-boundary flags from the wrapper (closes
    already accounts for the n_valid edge; starts is closes shifted right
    with starts[0] = 1).  n must be a multiple of ``block_size``; stream
    padding carries starts = closes = vals = 0 so it can never emit.

    Returns (rows_out, cols_out, vals_out, nnz[1]) with the ``nnz`` unique
    runs compacted into the leading slots and SENTINEL/zero fill after.
    """
    n = rows.shape[0]
    assert n % block_size == 0, (n, block_size)
    grid = (n // block_size,)
    blk = pl.BlockSpec((block_size,), lambda i: (i,))
    full = pl.BlockSpec((n,), lambda i: (0,))
    one = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _dedup_compact_kernel,
        grid=grid,
        in_specs=[blk] * 5,
        out_specs=[full, full, full, one],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), vals.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), vals.dtype),
        ],
        interpret=interpret,
    )(rows, cols, vals, starts, closes)
