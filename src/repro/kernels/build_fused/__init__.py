"""Fused GrB_Matrix_build kernel: radix sort + dedup-accumulate + compact."""
