"""Public wrapper for the fused build kernel (jit'd, CPU interpret fallback).

`fused_build` is the drop-in for `matrix_build`'s sort+dedup+compact body
under the `plus` dup monoid, returning the same `(rows, cols, vals, nnz)`
contract bit for bit.  The sort stage is mode-switched:

  * ``radix``  — the single-block Pallas LSD radix kernel (the TPU story;
    bounded by VMEM, see `RADIX_MAX_BYTES`);
  * ``xla``    — one variadic stable `lax.sort` over (rows, cols) with
    num_keys=2 (the CPU/interpret fallback: one sort instead of the oracle's
    two argsort+gather passes — roughly half the sort cost — because
    interpret-mode per-bin radix loops cannot beat XLA's native sort).

Both are *stable* lexicographic sorts, so their output is identical; the
fused dedup+compact Pallas kernel then runs in either mode (interpret on
CPU hosts), with block size chosen like `segsum`: whole-array single block
under interpret (grid-step overhead dominates there), `DEFAULT_BLOCK`
tiles on real TPUs (VMEM residency dominates there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.build_fused import kernel
from repro.core.hypersparse import SENTINEL

# single-block radix VMEM budget: operand streams must fit comfortably
RADIX_MAX_BYTES = 4 << 20


def _pad_to(arr, m, fill):
    n = arr.shape[0]
    if m == n:
        return arr
    return jnp.concatenate(
        [arr, jnp.full((m - n,), fill, arr.dtype)]
    )


def _pick_block(n: int, block_size: int | None, interpret: bool) -> int:
    if block_size is not None:
        return block_size
    if interpret or n <= kernel.DEFAULT_BLOCK:
        # one grid step: interpret-mode overhead is per step, not per element
        return max(128, -(-n // 128) * 128)
    return kernel.DEFAULT_BLOCK


def _resolve_sort_mode(sort_mode, interpret, n, n_streams):
    if sort_mode is not None:
        return sort_mode
    if interpret or n * n_streams * 4 > RADIX_MAX_BYTES:
        return "xla"
    return "radix"


def _sort_stage(rows, cols, payloads, sort_mode, interpret):
    if sort_mode == "radix":
        m = max(128, -(-rows.shape[0] // 128) * 128)
        # SENTINEL-key padding sorts last (stability keeps it after any
        # real SENTINEL entries, which were already in front of it)
        padded = [
            _pad_to(rows, m, SENTINEL),
            _pad_to(cols, m, SENTINEL),
        ] + [_pad_to(p, m, jnp.zeros((), p.dtype)) for p in payloads]
        outs = kernel.radix_sort_pairs(*padded, interpret=interpret)
        return tuple(o[: rows.shape[0]] for o in outs)
    if sort_mode == "xla":
        return jax.lax.sort(
            (rows, cols, *payloads), num_keys=2, is_stable=True
        )
    raise ValueError(f"unknown sort_mode {sort_mode!r}")


@functools.partial(
    jax.jit,
    static_argnames=("dtype", "block_size", "sort_mode", "interpret"),
)
def fused_build(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array | None = None,
    *,
    n_valid=None,
    dtype=jnp.int32,
    block_size: int | None = None,
    sort_mode: str | None = None,
    interpret: bool | None = None,
):
    """Sorted-COO build: sort by (row, col), sum duplicates, compact heads.

    ``vals=None`` is the counting build (values synthesized as the validity
    mask inside the pipeline — no payload rides through the sort).  Returns
    ``(rows, cols, vals, nnz)`` with unique sorted coordinates leading and
    SENTINEL/zero padding after — bit-identical to the jnp oracle
    (`ref.fused_build_ref` == `matrix_build`'s default path).
    """
    if interpret is None:
        interpret = default_interpret()
    rows = rows.astype(jnp.uint32)
    cols = cols.astype(jnp.uint32)
    n = rows.shape[0]
    counting = vals is None
    if n_valid is None:
        n_valid = jnp.int32(n)
    else:
        n_valid = jnp.asarray(n_valid, dtype=jnp.int32)

    # padding keys must sort last; validity stays a *prefix* through the
    # stable sort, so post-sort masks are still position < n_valid
    iota = jnp.arange(n, dtype=jnp.int32)
    valid = iota < n_valid
    rows = jnp.where(valid, rows, SENTINEL)
    cols = jnp.where(valid, cols, SENTINEL)

    n_streams = 2 if counting else 3
    mode = _resolve_sort_mode(sort_mode, interpret, n, n_streams)
    if counting:
        srows, scols = _sort_stage(rows, cols, (), mode, interpret)
        svals = valid.astype(dtype)  # run totals of 1s == run lengths
    else:
        srows, scols, svals = _sort_stage(
            rows, cols, (vals,), mode, interpret
        )
        svals = jnp.where(valid, svals, jnp.zeros((), svals.dtype))

    # run boundaries among the valid prefix, computed once in O(n):
    # a run closes at i when the (row, col) key changes at i+1 or i is the
    # last valid entry (a valid SENTINEL key must not merge into padding)
    key_change = jnp.concatenate(
        [
            (srows[:-1] != srows[1:]) | (scols[:-1] != scols[1:]),
            jnp.ones((1,), jnp.bool_),
        ]
    )
    closes = (valid & (key_change | (iota == n_valid - 1))).astype(jnp.int32)
    starts = jnp.concatenate([jnp.ones((1,), jnp.int32), closes[:-1]])

    bs = _pick_block(n, block_size, interpret)
    m = -(-n // bs) * bs
    r_out, c_out, v_out, nnz = kernel.dedup_compact(
        _pad_to(srows, m, SENTINEL),
        _pad_to(scols, m, SENTINEL),
        _pad_to(svals, m, jnp.zeros((), svals.dtype)),
        _pad_to(starts, m, jnp.int32(0)),
        _pad_to(closes, m, jnp.int32(0)),
        block_size=bs,
        interpret=interpret,
    )
    return r_out[:n], c_out[:n], v_out[:n], nnz[0]
