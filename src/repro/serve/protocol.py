"""Serve-protocol message kinds + socket address helpers.

Every message is one framelog frame (``RPFR`` magic, kind byte, u32
payload length, portable-pytree payload — see
:mod:`repro.checkpoint.framelog`), so the daemon socket protocol, the
exporter's off-box stream, and the on-disk dead-letter/export journals
all share one wire shape and one decoder.

Addresses are ``tcp://host:port`` or ``unix:///path`` (a bare path is
treated as a unix socket path).  ``tcp://host:0`` binds an ephemeral
port; the daemon reports the resolved address after bind.
"""

from __future__ import annotations

import socket
from pathlib import Path

# -- message kinds (frame kind byte) ----------------------------------------
MSG_INGEST = 0x01       # client -> daemon: one batch {"batch": uint32 array}
MSG_INGEST_END = 0x02   # client -> daemon: end of this client's stream
MSG_ACK = 0x06          # daemon -> client: acknowledgement {"received": n}
MSG_QUERY = 0x10        # client -> daemon: {"kind": ..., **params}
MSG_RESULT = 0x11       # daemon -> client: query result tree
MSG_EXPORT = 0x45       # exporter -> destination: one flagged-window record
MSG_ERROR = 0x7E        # daemon -> client: {"error": str}
MSG_SHUTDOWN = 0x7F     # client -> daemon: request drain + shutdown

KIND_NAMES = {
    MSG_INGEST: "ingest",
    MSG_INGEST_END: "ingest_end",
    MSG_ACK: "ack",
    MSG_QUERY: "query",
    MSG_RESULT: "result",
    MSG_EXPORT: "export",
    MSG_ERROR: "error",
    MSG_SHUTDOWN: "shutdown",
}


def parse_address(address: str) -> tuple[str, object]:
    """``tcp://host:port`` -> ("tcp", (host, port)); unix paths pass through."""
    if address.startswith("tcp://"):
        hostport = address[len("tcp://"):]
        host, _, port = hostport.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad tcp address {address!r} "
                             "(want tcp://host:port)")
        return "tcp", (host, int(port))
    if address.startswith("unix://"):
        return "unix", address[len("unix://"):]
    return "unix", address


def format_address(family: str, addr) -> str:
    if family == "tcp":
        host, port = addr[0], addr[1]
        return f"tcp://{host}:{port}"
    return f"unix://{addr}"


def listen(address: str, backlog: int = 32) -> tuple[socket.socket, str]:
    """Bind + listen; returns (server socket, resolved address string)."""
    family, addr = parse_address(address)
    if family == "tcp":
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind(addr)
        srv.listen(backlog)
        return srv, format_address("tcp", srv.getsockname())
    path = Path(addr)
    if path.exists():
        path.unlink()
    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    srv.bind(str(path))
    srv.listen(backlog)
    return srv, format_address("unix", str(path))


def connect(address: str, timeout: float | None = None) -> socket.socket:
    family, addr = parse_address(address)
    if family == "tcp":
        return socket.create_connection(addr, timeout=timeout)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    if timeout is not None:
        sock.settimeout(timeout)
    sock.connect(str(addr))
    sock.settimeout(None)
    return sock
