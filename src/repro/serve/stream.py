"""StreamQueueSource: the daemon's bridge from socket ingest to the engine.

A bounded queue that *is* an engine ``Source``: ingest handler threads
``put`` validated batches, the engine's policy loop iterates them off the
other end.  The bound is the daemon's backpressure — when the engine
falls behind, ``put`` blocks, the ingest thread stops reading its
socket, and TCP flow control pushes back on the client.  ``close()``
ends the stream (the engine's run drains what is queued and returns),
which is how SIGTERM becomes a clean run-to-completion.
"""

from __future__ import annotations

import queue
import threading
import time

import numpy as np

from repro.engine.source import Source


class StreamQueueSource(Source):
    """Thread-safe bounded batch queue, iterable exactly once."""

    def __init__(self, *, window_size: int, windows_per_batch: int,
                 maxsize: int = 8, record_width: int = 2):
        self.window_size = int(window_size)
        self.windows_per_batch = int(windows_per_batch)
        self.record_width = int(record_width)
        self.packets_per_item = self.window_size * self.windows_per_batch
        self._q: queue.Queue = queue.Queue(maxsize)
        self._lock = threading.Lock()
        self._closed = False
        self._accepted = 0

    @property
    def batch_shape(self) -> tuple[int, int, int]:
        return (self.windows_per_batch, self.window_size, self.record_width)

    def validate(self, batch) -> np.ndarray:
        """Coerce one ingest payload to the engine's batch shape/dtype."""
        arr = np.asarray(batch)
        if arr.dtype != np.uint32:
            raise ValueError(f"ingest batch dtype must be uint32, "
                             f"got {arr.dtype}")
        want = self.batch_shape
        if arr.ndim == 2 and arr.shape[1] == self.record_width:
            if arr.shape[0] != want[0] * want[1]:
                raise ValueError(
                    f"flat ingest batch has {arr.shape[0]} records, "
                    f"want {want[0] * want[1]}"
                )
            arr = arr.reshape(want)
        if arr.shape != want:
            raise ValueError(f"ingest batch shape {arr.shape} != {want}")
        return np.ascontiguousarray(arr)

    def put(self, batch, timeout: float | None = None) -> int:
        """Enqueue one batch (blocking = backpressure); returns its
        0-based stream position.

        Blocks in short slices so a producer stuck behind a full queue
        still observes ``close()`` promptly (raising instead of
        deadlocking against an engine that already exited).
        """
        arr = self.validate(batch)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            with self._lock:
                if self._closed:
                    raise RuntimeError("stream is closed")
            try:
                self._q.put(arr, timeout=0.1)
                break
            except queue.Full:
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"ingest queue full for {timeout}s"
                    ) from None
        with self._lock:
            pos = self._accepted
            self._accepted += 1
        return pos

    def close(self) -> None:
        """End the stream: the engine drains queued batches and returns.

        Never blocks — the iterator polls, so a full queue with no
        consumer (engine already crashed) cannot deadlock shutdown.
        """
        with self._lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def accepted(self) -> int:
        with self._lock:
            return self._accepted

    def qsize(self) -> int:
        return self._q.qsize()

    def __iter__(self):
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                if self.closed:
                    return
                continue
            yield item
