"""Always-on analytics service: engine daemon + ingest/query protocol.

The run-to-drain batch engine (``repro.engine``) becomes a long-running
collector: ``AnalyticsDaemon`` feeds a socket ingest stream through
``TrafficEngine`` under any execution policy, retains hierarchical
power-of-two roll-ups (``RollupSink``), ships flagged windows off-box
(``ExporterSink``), and answers concurrent queries over the retained
hierarchy — all while honoring ``FaultTolerance`` and checkpoint/resume.
See DESIGN.md §"Always-on service".
"""

from repro.serve.client import DaemonClient, IngestClient, collect_exports
from repro.serve.daemon import AnalyticsDaemon
from repro.serve.exporter import ExporterSink
from repro.serve.rollup import RollupSink
from repro.serve.stream import StreamQueueSource

__all__ = [
    "AnalyticsDaemon",
    "DaemonClient",
    "ExporterSink",
    "IngestClient",
    "RollupSink",
    "StreamQueueSource",
    "collect_exports",
]
