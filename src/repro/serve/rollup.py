"""RollupSink: hierarchical power-of-two aggregates of batch matrices.

Level 0 retains per-batch merged matrices; level ``l`` retains exact
sums of ``2^l`` consecutive batches, built with the same
``ops.ewise_add`` merge primitive the in-batch window tree uses.  The
maintenance scheme is a binary counter (LSM-style): each level holds at
most one *pending* half-aggregate; when its sibling arrives the two
merge into one level-``l+1`` aggregate and the carry propagates.  Every
batch therefore costs amortized O(1) merges, and an aggregate over
``[s, s + 2^l)`` is bit-identical to folding those batches' matrices
pairwise — integer addition over disjoint batch spans is associative,
so exactness is preserved as long as no merge overflows its capacity
(overflow is counted and reported, never silent).

Queries (top-k links/talkers, fan-out histogram, window stats, diffs
between aggregates) run against host-retained matrices under the sink
lock, so many concurrent daemon clients can read while the engine loop
writes.
"""

from __future__ import annotations

import threading

import jax
import numpy as np

from repro.core import analytics, ops, types
from repro.core.hypersparse import HypersparseMatrix
from repro.core.window import WindowConfig
from repro.engine.sinks import Sink


def _mat_to_state(m: HypersparseMatrix) -> dict:
    h = jax.device_get(m)
    return {
        "rows": np.asarray(h.rows),
        "cols": np.asarray(h.cols),
        "vals": np.asarray(h.vals),
        "nnz": np.asarray(h.nnz),
        "nrows": int(h.nrows),
        "ncols": int(h.ncols),
    }


def _mat_from_state(d: dict) -> HypersparseMatrix:
    return HypersparseMatrix(
        rows=d["rows"], cols=d["cols"], vals=d["vals"], nnz=d["nnz"],
        nrows=int(d["nrows"]), ncols=int(d["ncols"]),
    )


def _entries(m: HypersparseMatrix, *, drop_zero: bool = False) -> dict:
    """Valid (row, col, val) triples of a host matrix."""
    h = jax.device_get(m)
    rows = np.asarray(h.rows)
    nnz = int(np.asarray(h.nnz))
    rows, cols, vals = (rows[:nnz], np.asarray(h.cols)[:nnz],
                        np.asarray(h.vals)[:nnz])
    if drop_zero:
        keep = vals != 0
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    return {"rows": rows.astype(np.uint32), "cols": cols.astype(np.uint32),
            "vals": vals, "nnz": int(rows.shape[0])}


class RollupSink(Sink):
    """Retain a multi-resolution hierarchy of exact batch-matrix sums."""

    name = "rollup"
    requires = ("matrix",)

    def __init__(self, cfg: WindowConfig, *, levels: int = 4,
                 keep_per_level: int = 4):
        if levels < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        self.cfg = cfg
        self.levels = int(levels)
        self.keep_per_level = int(keep_per_level)
        self._lock = threading.RLock()
        # completed aggregates, oldest first, ring-capped per level
        self._completed: list[list[dict]] = [[] for _ in range(self.levels)]
        # at most one pending half-aggregate per level (binary counter)
        self._pending: list[dict | None] = [None] * self.levels
        self._batches = 0
        self._overflow = 0  # entries dropped by roll-up merges (not builds)

    def _capacity(self, level: int, base_cap: int) -> int:
        return int(min(base_cap << level, self.cfg.cap_max))

    def consume(self, index: int, outputs: dict) -> None:
        m = jax.device_get(outputs["matrix"])
        with self._lock:
            base_cap = int(np.asarray(m.rows).shape[0])
            carry = {"start": self._batches, "span": 1, "matrix": m}
            self._batches += 1
            for level in range(self.levels):
                done = self._completed[level]
                done.append(carry)
                if len(done) > self.keep_per_level:
                    done.pop(0)
                if level == self.levels - 1:
                    break
                pending = self._pending[level]
                if pending is None:
                    self._pending[level] = carry
                    break
                merged, ovf = ops.ewise_add(
                    pending["matrix"], carry["matrix"], types.PLUS,
                    out_capacity=self._capacity(level + 1, base_cap),
                )
                self._overflow += int(np.asarray(ovf))
                self._pending[level] = None
                carry = {
                    "start": pending["start"],
                    "span": pending["span"] + carry["span"],
                    "matrix": jax.device_get(merged),
                }

    def finalize(self) -> dict:
        with self._lock:
            return self.status()

    # -- query API ----------------------------------------------------------
    # All queries return host trees (numpy arrays / python scalars) that
    # round-trip the portable pytree encoding — directly servable as
    # MSG_RESULT payloads.

    def _get(self, level: int, index: int) -> dict:
        if not 0 <= level < self.levels:
            raise ValueError(
                f"level {level} out of range [0, {self.levels})"
            )
        done = self._completed[level]
        if not done:
            raise ValueError(f"no completed aggregates at level {level}")
        try:
            return done[index]
        except IndexError:
            raise ValueError(
                f"aggregate index {index} out of range for level {level} "
                f"({len(done)} retained)"
            ) from None

    def status(self) -> dict:
        with self._lock:
            return {
                "batches": self._batches,
                "rollup_overflow": self._overflow,
                "levels": [
                    {
                        "level": lvl,
                        "span": 1 << lvl,
                        "retained": len(done),
                        "pending": self._pending[lvl] is not None
                        if lvl < self.levels - 1 else False,
                    }
                    for lvl, done in enumerate(self._completed)
                ],
            }

    def levels_summary(self) -> dict:
        with self._lock:
            return {
                "levels": [
                    [
                        {"start": a["start"], "span": a["span"],
                         "nnz": int(np.asarray(a["matrix"].nnz))}
                        for a in done
                    ]
                    for done in self._completed
                ]
            }

    def top_links(self, k: int = 10, *, level: int = 0,
                  index: int = -1) -> dict:
        with self._lock:
            agg = self._get(level, index)
            rows, cols, counts = jax.device_get(
                analytics.top_k_heavy_hitters(agg["matrix"], int(k))
            )
        keep = np.asarray(counts) > 0
        return {
            "start": agg["start"], "span": agg["span"],
            "rows": np.asarray(rows)[keep],
            "cols": np.asarray(cols)[keep],
            "counts": np.asarray(counts)[keep],
        }

    def top_talkers(self, k: int = 10, *, level: int = 0,
                    index: int = -1) -> dict:
        with self._lock:
            agg = self._get(level, index)
            sources, counts = jax.device_get(
                analytics.top_k_sources(agg["matrix"], int(k))
            )
        keep = np.asarray(counts) > 0
        return {
            "start": agg["start"], "span": agg["span"],
            "sources": np.asarray(sources)[keep],
            "counts": np.asarray(counts)[keep],
        }

    def fanout(self, *, level: int = 0, index: int = -1) -> dict:
        with self._lock:
            agg = self._get(level, index)
            hist = jax.device_get(analytics.src_fanout_hist(agg["matrix"]))
        return {"start": agg["start"], "span": agg["span"],
                "hist": np.asarray(hist)}

    def window_stats(self, *, level: int = 0, index: int = -1) -> dict:
        with self._lock:
            agg = self._get(level, index)
            stats = jax.device_get(analytics.window_stats(agg["matrix"]))
        out = {k: np.asarray(v) for k, v in stats.items()}
        out.update(start=agg["start"], span=agg["span"])
        return out

    def diff(self, *, level: int = 0, index_a: int = -1,
             index_b: int = 0) -> dict:
        """Entrywise A - B between two same-level aggregates (what changed
        between two spans of the stream); zero-delta entries dropped."""
        with self._lock:
            a = self._get(level, index_a)
            b = self._get(level, index_b)
            neg_b = ops.apply(b["matrix"], types.AINV)
            cap = int(np.asarray(a["matrix"].rows).shape[0]) + int(
                np.asarray(b["matrix"].rows).shape[0]
            )
            delta, ovf = ops.ewise_add(
                a["matrix"], neg_b, types.PLUS,
                out_capacity=min(cap, self.cfg.cap_max * 2),
            )
        out = _entries(delta, drop_zero=True)
        out.update(
            a={"start": a["start"], "span": a["span"]},
            b={"start": b["start"], "span": b["span"]},
            overflow=int(np.asarray(ovf)),
        )
        return out

    # -- checkpointing ------------------------------------------------------

    def state_dict(self) -> dict:
        def enc(agg):
            return {"start": int(agg["start"]), "span": int(agg["span"]),
                    "matrix": _mat_to_state(agg["matrix"])}

        with self._lock:
            return {
                "batches": self._batches,
                "overflow": self._overflow,
                "completed": [[enc(a) for a in done]
                              for done in self._completed],
                "pending": [enc(p) if p is not None else None
                            for p in self._pending],
            }

    def load_state_dict(self, state: dict) -> None:
        def dec(d):
            return {"start": int(d["start"]), "span": int(d["span"]),
                    "matrix": _mat_from_state(d["matrix"])}

        completed = [[dec(a) for a in done] for done in state["completed"]]
        pending = [dec(p) if p is not None else None
                   for p in state["pending"]]
        if len(completed) != self.levels or len(pending) != self.levels:
            raise ValueError(
                f"rollup checkpoint has {len(completed)} levels, "
                f"sink configured with {self.levels}"
            )
        with self._lock:
            self._batches = int(state["batches"])
            self._overflow = int(state["overflow"])
            self._completed = completed
            self._pending = pending
