"""ExporterSink: ship flagged windows off-box.

The deployment papers' edge pattern ("GraphBLAS on the Edge",
2203.13934): the collector keeps the full matrix stream local and
exports only *flagged* windows — anomaly-scored or threshold-crossing —
to a central destination.  Records are framelog frames (``MSG_EXPORT``)
of portable pytrees, so the destination can be a file (append-only
journal, crash/resume safe via byte cursor) or a socket
(``tcp://host:port`` / ``unix://path``) speaking the same framing as the
serve protocol.

Flagging is *streaming and causal*, unlike ``AnomalySink``'s
retrospective finalize-time z-score: each window's fan-out histogram is
scored against the running mean/std of all windows seen before it
(Welford), then folded in.  For a fixed stream the flag sequence is
deterministic — which is what makes daemon-mode exports reproducible
and checkpoint/resume exact.
"""

from __future__ import annotations

import numpy as np

from repro.core.analytics import HIST_BINS
from repro.engine.sinks import Sink
from repro.serve import protocol


class ExporterSink(Sink):
    """Serialize flagged windows to a file or socket destination.

    ``rule="zscore"`` flags a window when any histogram bin deviates
    from the running mean by at least ``threshold`` standard deviations
    (after ``min_windows`` windows of history); ``rule="count"`` flags a
    batch when its heaviest link meets ``threshold`` packets.  Each
    export record carries the batch index, flagged window offsets,
    scores, and (optionally) the batch's merged matrix.
    """

    name = "exporter"
    requires = ("matrix", "fanout_hist")

    def __init__(self, destination: str, *, rule: str = "zscore",
                 threshold: float = 3.0, min_windows: int = 8,
                 keep_matrix: bool = True):
        if rule not in ("zscore", "count"):
            raise ValueError(f"rule must be 'zscore' or 'count', got {rule!r}")
        self.destination = str(destination)
        self.rule = rule
        self.threshold = float(threshold)
        self.min_windows = int(min_windows)
        self.keep_matrix = keep_matrix
        self._is_socket = self.destination.startswith(("tcp://", "unix://"))
        self._log = None
        self._sock_io = None
        # Welford running stats over per-window fan-out histograms
        self._count = 0
        self._mean = np.zeros((HIST_BINS,), np.float64)
        self._m2 = np.zeros((HIST_BINS,), np.float64)
        self._batches = 0
        self.exported = 0

    # -- destination plumbing ------------------------------------------------

    def _writer(self):
        if self._is_socket:
            if self._sock_io is None:
                from repro.checkpoint.framelog import SocketFrameIO

                self._sock_io = SocketFrameIO(
                    protocol.connect(self.destination)
                )
            return self._sock_io
        if self._log is None:
            from repro.checkpoint.framelog import FrameLog

            path = self.destination
            if path.startswith("file://"):
                path = path[len("file://"):]
            self._log = FrameLog(path)
        return self._log

    def _emit(self, record: dict) -> None:
        writer = self._writer()
        if self._is_socket:
            writer.send(protocol.MSG_EXPORT, record)
        else:
            writer.append(protocol.MSG_EXPORT, record)
        self.exported += 1

    def close(self) -> None:
        if self._log is not None:
            self._log.close()
        if self._sock_io is not None:
            self._sock_io.close()
            self._sock_io = None

    # -- flagging ------------------------------------------------------------

    def _score_batch(self, hists: np.ndarray) -> tuple[list[int], list[float]]:
        """Causal z-scores for each window row; updates running stats."""
        flagged, scores = [], []
        for w in range(hists.shape[0]):
            h = hists[w].astype(np.float64)
            if self._count >= self.min_windows:
                std = np.sqrt(self._m2 / self._count)
                # std floor of 1.0: these are count histograms, and a
                # perfectly constant history (std == 0) must still flag a
                # deviation — scored as raw packet counts
                z = np.abs(h - self._mean) / np.maximum(std, 1.0)
                score = float(z.max())
                if score >= self.threshold:
                    flagged.append(w)
                    scores.append(score)
            self._count += 1
            delta = h - self._mean
            self._mean += delta / self._count
            self._m2 += delta * (h - self._mean)
        return flagged, scores

    def consume(self, index: int, outputs: dict) -> None:
        import jax

        batch_index = self._batches
        self._batches += 1
        hists = np.asarray(jax.device_get(outputs["fanout_hist"]))
        if self.rule == "zscore":
            flagged, scores = self._score_batch(hists)
        else:
            m = jax.device_get(outputs["matrix"])
            nnz = int(np.asarray(m.nnz))
            peak = int(np.asarray(m.vals)[:nnz].max()) if nnz else 0
            flagged = list(range(hists.shape[0])) if (
                peak >= self.threshold) else []
            scores = [float(peak)] * len(flagged)
        if not flagged:
            return
        record: dict = {
            "batch": int(batch_index),
            "rule": self.rule,
            "threshold": self.threshold,
            "windows": [int(w) for w in flagged],
            "scores": [float(s) for s in scores],
        }
        if self.keep_matrix:
            from repro.serve.rollup import _mat_to_state

            record["matrix"] = _mat_to_state(outputs["matrix"])
        self._emit(record)

    def finalize(self) -> dict:
        self.close()
        return {
            "destination": self.destination,
            "rule": self.rule,
            "threshold": self.threshold,
            "batches": self._batches,
            "exported": self.exported,
        }

    # -- checkpointing -------------------------------------------------------
    # File destinations resume exactly-once: the byte cursor checkpointed
    # here truncates the journal back to the durable prefix and replayed
    # batches re-append bit-identically.  Socket destinations cannot be
    # truncated, so a resumed run may re-send records for replayed batches
    # (at-least-once) — the record's ``batch`` index makes the receiver's
    # dedup trivial.

    def state_dict(self) -> dict:
        state = {
            "count": self._count,
            "mean": self._mean.copy(),
            "m2": self._m2.copy(),
            "batches": self._batches,
            "exported": self.exported,
        }
        if not self._is_socket:
            state["log_pos"] = int(self._writer().tell())
        return state

    def load_state_dict(self, state: dict) -> None:
        self._count = int(state["count"])
        self._mean = np.asarray(state["mean"], np.float64).copy()
        self._m2 = np.asarray(state["m2"], np.float64).copy()
        self._batches = int(state["batches"])
        self.exported = int(state["exported"])
        if not self._is_socket and "log_pos" in state:
            from repro.checkpoint.framelog import FrameLog

            path = self.destination
            if path.startswith("file://"):
                path = path[len("file://"):]
            self._log = FrameLog(path)
            self._log.truncate_to(int(state["log_pos"]))
