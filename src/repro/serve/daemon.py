"""AnalyticsDaemon: TrafficEngine as a long-running socket service.

Lifecycle::

    daemon = AnalyticsDaemon(cfg, policy="async_pipelined",
                             rollup_levels=4, export="flags.rpfr",
                             checkpoint_dir="ckpts", checkpoint_every=4)
    addr = daemon.bind("tcp://127.0.0.1:0")
    daemon.start()                  # engine drain loop + acceptor threads
    ...                             # clients ingest / query via `addr`
    daemon.shutdown()               # or a client sends MSG_SHUTDOWN / SIGTERM
    report = daemon.join()          # EngineReport; final checkpoint written
    results = daemon.finalize()     # sink results, handles closed

Ingest handler threads push validated batches into a bounded
``StreamQueueSource``; the engine's execution policy drains it exactly
as it drains a batch source — same stage graph, same sinks, same
accounting — which is why daemon-mode stats and retained matrices are
bit-identical to a batch run over the same stream (the equivalence
tests pin this over ``canonical_policies()``).  Shutdown closes the
stream; the engine finishes everything already accepted, writes a final
checkpoint (``TrafficEngine.checkpoint_now``), and a later start with
``resume=True`` continues from the cursor while clients replay from
stream start (``fast_forward`` skips what was already consumed).
"""

from __future__ import annotations

import threading
import warnings

from repro.core.window import WindowConfig
from repro.data.flows import FLOW_WIDTH
from repro.engine.engine import TrafficEngine
from repro.engine.faults import FaultTolerance
from repro.engine.sinks import Sink, StatsAccumulator
from repro.engine.telemetry import EngineReport
from repro.serve import protocol
from repro.serve.exporter import ExporterSink
from repro.serve.rollup import RollupSink
from repro.serve.stream import StreamQueueSource


class DaemonError(RuntimeError):
    """A query/ingest request the daemon rejected."""


class AnalyticsDaemon:
    def __init__(
        self,
        cfg: WindowConfig,
        *,
        workload: str = "packets",
        policy: str = "blocking",
        sinks: list[Sink] | None = None,
        rollup_levels: int = 0,
        rollup_keep: int = 4,
        export: str | None = None,
        export_rule: str = "zscore",
        export_threshold: float = 3.0,
        fault_tolerance: FaultTolerance | None = None,
        checkpoint_manager=None,
        checkpoint_every: int = 0,
        resume: bool = False,
        queue_depth: int = 8,
    ):
        self.cfg = cfg
        engine_sinks: list[Sink] = list(sinks) if sinks is not None else [
            StatsAccumulator()
        ]
        self.rollup: RollupSink | None = None
        if rollup_levels:
            self.rollup = RollupSink(cfg, levels=rollup_levels,
                                     keep_per_level=rollup_keep)
            engine_sinks.append(self.rollup)
        self.exporter: ExporterSink | None = None
        if export:
            self.exporter = ExporterSink(export, rule=export_rule,
                                         threshold=export_threshold)
            engine_sinks.append(self.exporter)
        self.engine = TrafficEngine(cfg, workload=workload, policy=policy,
                                    sinks=engine_sinks)
        self.stream = StreamQueueSource(
            window_size=cfg.window_size,
            windows_per_batch=cfg.windows_per_batch,
            maxsize=queue_depth,
            record_width=FLOW_WIDTH if workload == "flow" else 2,
        )
        self._ft = fault_tolerance
        self._ckpt_mgr = checkpoint_manager
        self._ckpt_every = int(checkpoint_every)
        self._resume = bool(resume)
        if (self._ckpt_every or resume) and checkpoint_manager is None:
            raise ValueError(
                "checkpoint_every/resume require a checkpoint_manager"
            )

        self._lock = threading.Lock()
        self._listener = None
        self.address: str | None = None
        self._threads: list[threading.Thread] = []
        self._conns: list = []
        self._engine_thread: threading.Thread | None = None
        self._shutting_down = False
        self.report: EngineReport | None = None
        self._error: BaseException | None = None
        self._dropped = 0

    # -- lifecycle -----------------------------------------------------------

    def bind(self, address: str) -> str:
        """Bind the ingest/query socket; returns the resolved address
        (``tcp://host:0`` picks an ephemeral port)."""
        self._listener, self.address = protocol.listen(address)
        # poll-style accept: closing a listener from another thread does
        # not reliably wake a blocked accept(), a timeout loop does
        self._listener.settimeout(0.2)
        return self.address

    def start(self) -> None:
        """Run acceptor + engine drain loop on background threads."""
        if self._listener is None:
            raise RuntimeError("call bind() before start()")
        acceptor = threading.Thread(target=self._accept_loop, daemon=True,
                                    name="repro-serve-accept")
        self._threads.append(acceptor)
        acceptor.start()
        self._engine_thread = threading.Thread(
            target=self._engine_loop, daemon=True, name="repro-serve-engine"
        )
        self._engine_thread.start()

    def serve_forever(self) -> EngineReport:
        """Blocking form of start()+join() (the CLI's main thread)."""
        self.start()
        return self.join()

    def shutdown(self) -> None:
        """Stop accepting, end the stream; the engine drains and exits."""
        with self._lock:
            if self._shutting_down:
                return
            self._shutting_down = True
            listener, self._listener = self._listener, None
            conns = list(self._conns)
        if listener is not None:
            try:
                listener.close()
            except OSError as e:
                warnings.warn(f"listener close failed: {e!r}",
                              RuntimeWarning, stacklevel=2)
        # Closing client connections first stops new ingest racing the
        # stream sentinel; anything already queued still drains.
        for io in conns:
            io.close()
        self.stream.close()

    def join(self, timeout: float | None = None) -> EngineReport:
        """Wait for the engine drain loop; re-raises its failure."""
        if self._engine_thread is None:
            raise RuntimeError("daemon not started")
        self._engine_thread.join(timeout)
        if self._engine_thread.is_alive():
            raise TimeoutError("daemon engine loop still running")
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=5.0)
        if self._error is not None:
            raise self._error
        return self.report

    def finalize(self) -> dict:
        return self.engine.finalize()

    # -- engine drain loop ---------------------------------------------------

    def _engine_loop(self) -> None:
        try:
            report = self.engine.run(
                self.stream,
                warmup_items=0,
                keep_results=False,
                fault_tolerance=self._ft,
                checkpoint_every=self._ckpt_every,
                checkpoint_manager=self._ckpt_mgr,
                resume=self._resume,
            )
            if self._ckpt_mgr is not None:
                self.engine.checkpoint_now()
                self._ckpt_mgr.wait()
            dropped = self.stream.qsize()
            with self._lock:
                self.report = report
                self._dropped = dropped
            if dropped:
                warnings.warn(
                    f"{dropped} ingested batch(es) raced shutdown and were "
                    "not processed (arrived after the stream closed); "
                    "clients should replay from the checkpoint cursor",
                    RuntimeWarning, stacklevel=2,
                )
        except BaseException as e:  # noqa: BLE001 - re-raised at join()
            with self._lock:
                self._error = e
        finally:
            # engine exit (clean or not) tears down the socket plane so
            # handler threads unblock and join() completes
            self.shutdown()

    # -- socket plane --------------------------------------------------------

    def _accept_loop(self) -> None:
        from repro.checkpoint.framelog import SocketFrameIO

        while True:
            with self._lock:
                listener = self._listener
            if listener is None:
                return
            try:
                conn, _ = listener.accept()
            except TimeoutError:
                continue  # poll interval expired; re-check for shutdown
            except OSError:
                # listener closed by shutdown(): the accept loop's normal
                # exit path, not an error
                return
            conn.settimeout(None)  # handlers block on recv, no polling
            io = SocketFrameIO(conn)
            with self._lock:
                if self._shutting_down:
                    io.close()
                    return
                self._conns.append(io)
                n = len(self._conns)
                handler = threading.Thread(
                    target=self._handle_conn, args=(io,), daemon=True,
                    name=f"repro-serve-conn-{n}",
                )
                self._threads.append(handler)
            handler.start()

    def _handle_conn(self, io) -> None:
        received = 0
        try:
            while True:
                try:
                    frame = io.recv()
                except (OSError, EOFError, ValueError) as e:
                    if not self._shutting_down:
                        warnings.warn(
                            f"client connection dropped: {e!r}",
                            RuntimeWarning, stacklevel=2,
                        )
                    return
                if frame is None:
                    return
                kind, tree = frame
                if kind == protocol.MSG_INGEST:
                    try:
                        self.stream.put(tree["batch"])
                        received += 1
                    except (RuntimeError, ValueError, KeyError,
                            TypeError) as e:
                        io.send(protocol.MSG_ERROR, {"error": str(e)})
                elif kind == protocol.MSG_INGEST_END:
                    io.send(protocol.MSG_ACK, {"received": received})
                elif kind == protocol.MSG_QUERY:
                    self._answer_query(io, tree)
                elif kind == protocol.MSG_SHUTDOWN:
                    io.send(protocol.MSG_ACK, {"stopping": True})
                    self.shutdown()
                    return
                else:
                    io.send(protocol.MSG_ERROR,
                            {"error": f"unknown message kind {kind:#x}"})
        except OSError as e:
            # peer vanished mid-reply; the daemon keeps serving others
            if not self._shutting_down:
                warnings.warn(f"client connection error: {e!r}",
                              RuntimeWarning, stacklevel=2)
        finally:
            io.close()

    def _answer_query(self, io, req) -> None:
        try:
            result = self.query(req)
        except (DaemonError, ValueError, KeyError, TypeError) as e:
            io.send(protocol.MSG_ERROR, {"error": str(e)})
            return
        io.send(protocol.MSG_RESULT, result)

    # -- query API -----------------------------------------------------------

    def query(self, req: dict) -> dict:
        """Answer one query request (also callable in-process)."""
        kind = req.get("kind")
        if kind == "status":
            return self._status()
        if kind not in ("levels", "top_links", "top_talkers", "fanout",
                        "stats", "diff"):
            raise DaemonError(f"unknown query kind {kind!r}")
        rollup = self.rollup
        if rollup is None:
            raise DaemonError(
                f"query {kind!r} needs the roll-up hierarchy; start the "
                "daemon with rollup_levels >= 1"
            )
        if kind == "levels":
            return rollup.levels_summary()
        if kind == "top_links":
            return rollup.top_links(int(req.get("k", 10)),
                                    level=int(req.get("level", 0)),
                                    index=int(req.get("index", -1)))
        if kind == "top_talkers":
            return rollup.top_talkers(int(req.get("k", 10)),
                                      level=int(req.get("level", 0)),
                                      index=int(req.get("index", -1)))
        if kind == "fanout":
            return rollup.fanout(level=int(req.get("level", 0)),
                                 index=int(req.get("index", -1)))
        if kind == "stats":
            return rollup.window_stats(level=int(req.get("level", 0)),
                                       index=int(req.get("index", -1)))
        if kind == "diff":
            return rollup.diff(level=int(req.get("level", 0)),
                               index_a=int(req.get("index_a", -1)),
                               index_b=int(req.get("index_b", 0)))
        raise DaemonError(f"unknown query kind {kind!r}")

    def _status(self) -> dict:
        out = {
            "address": self.address or "",
            "accepted": self.stream.accepted,
            "queued": self.stream.qsize(),
            "consumed": self.engine.batches_consumed,
            "shutting_down": self._shutting_down,
            "exported": self.exporter.exported if self.exporter else 0,
            "dropped": self._dropped,
        }
        if self.rollup is not None:
            out["rollup"] = self.rollup.status()
        return out
