"""Client side of the serve protocol: ingest streams + queries.

``IngestClient`` pushes batches (fire-and-forget; the daemon reports
validation failures asynchronously and acknowledges ``end()`` with the
count it accepted).  ``DaemonClient`` is the query/control plane — one
connection per client, many clients per daemon.  Both are thin wrappers
over the shared framelog wire format, so anything that speaks
``RPFR`` frames (including a netcat-grade reimplementation) interops.
"""

from __future__ import annotations

import time

import numpy as np

from repro.checkpoint.framelog import FrameLog, SocketFrameIO
from repro.serve import protocol


class DaemonRequestError(RuntimeError):
    """The daemon answered with MSG_ERROR."""


class _Conn:
    def __init__(self, address: str, timeout: float | None = 30.0):
        self.address = address
        self._io = SocketFrameIO(protocol.connect(address, timeout=timeout))

    def close(self) -> None:
        self._io.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _request(self, kind: int, tree) -> tuple[int, object]:
        self._io.send(kind, tree)
        reply = self._io.recv()
        if reply is None:
            raise ConnectionError(
                f"daemon at {self.address} closed the connection"
            )
        rk, rtree = reply
        if rk == protocol.MSG_ERROR:
            raise DaemonRequestError(rtree.get("error", "unknown error"))
        return rk, rtree


class DaemonClient(_Conn):
    """Query + control connection."""

    def query(self, kind: str, **params) -> dict:
        req = {"kind": kind}
        req.update(params)
        _, tree = self._request(protocol.MSG_QUERY, req)
        return tree

    def status(self) -> dict:
        return self.query("status")

    def wait_consumed(self, n: int, *, timeout: float = 30.0,
                      poll_s: float = 0.02) -> dict:
        """Poll status until the daemon has consumed >= n batches —
        the barrier tests/CI use before asserting deterministic query
        results."""
        deadline = time.monotonic() + timeout
        while True:
            status = self.status()
            if int(status["consumed"]) >= n:
                return status
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"daemon consumed {status['consumed']}/{n} batches "
                    f"within {timeout}s"
                )
            time.sleep(poll_s)

    def shutdown(self) -> dict:
        _, tree = self._request(protocol.MSG_SHUTDOWN, {})
        return tree


class IngestClient(_Conn):
    """Streaming ingest connection."""

    def __init__(self, address: str, timeout: float | None = 30.0):
        super().__init__(address, timeout=timeout)
        self.sent = 0

    def send_batch(self, batch: np.ndarray) -> None:
        self._io.send(protocol.MSG_INGEST,
                      {"batch": np.ascontiguousarray(batch)})
        self.sent += 1

    def send_stream(self, batches) -> int:
        for batch in batches:
            self.send_batch(batch)
        return self.sent

    def end(self) -> dict:
        """Flush the stream; returns the daemon's {"received": n} ack.

        Raises ``DaemonRequestError`` carrying the daemon's first
        buffered validation error, if any batch was rejected.
        """
        _, tree = self._request(protocol.MSG_INGEST_END, {})
        if int(tree.get("received", -1)) != self.sent:
            raise DaemonRequestError(
                f"daemon accepted {tree.get('received')} of {self.sent} "
                "batches (a batch failed validation; see daemon warnings)"
            )
        return tree


def collect_exports(path) -> list[dict]:
    """Decode an ExporterSink file destination into its records."""
    return [tree for kind, tree in FrameLog.read_all(path)
            if kind == protocol.MSG_EXPORT]
