"""Transformer building blocks: norms, RoPE, GQA attention (train + cached
decode), gated MLPs.

Pure-function style: ``init_*`` builds parameter pytrees (plain dicts),
``*_apply`` consumes them. Params are kept in ``param_dtype`` (fp32 by
default) and compute runs in ``dtype`` (bf16 for LM configs), matching
standard mixed-precision training. Sharding is applied at jit boundaries by
``distributed.sharding``; the layer code is mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    causal: bool = True


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, param_dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), param_dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    scale = jnp.broadcast_to(params["scale"].astype(jnp.float32), xf.shape)
    return (normed * scale).astype(x.dtype)


def init_layernorm(d: int, param_dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), param_dtype),
            "bias": jnp.zeros((d,), param_dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    scale = jnp.broadcast_to(params["scale"].astype(jnp.float32), xf.shape)
    bias = jnp.broadcast_to(params["bias"].astype(jnp.float32), xf.shape)
    out = normed * scale + bias
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d_head]; positions: [..., seq] int32."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # [d_head/2]
    freqs = freqs.reshape((1,) * positions.ndim + (-1,))  # [1..., 1, dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., s, dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., s, 1, dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(
    key, cfg: AttentionConfig, param_dtype=jnp.float32
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, nh, nkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, nh * dh), param_dtype) * scale,
        "wk": jax.random.normal(k2, (d, nkv * dh), param_dtype) * scale,
        "wv": jax.random.normal(k3, (d, nkv * dh), param_dtype) * scale,
        "wo": jax.random.normal(k4, (nh * dh, d), param_dtype) * scale,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * dh,), param_dtype)
        p["bk"] = jnp.zeros((nkv * dh,), param_dtype)
        p["bv"] = jnp.zeros((nkv * dh,), param_dtype)
    return p


def _qkv(params, x, cfg: AttentionConfig):
    b, s, _ = x.shape
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + jnp.broadcast_to(params["bq"].astype(x.dtype), q.shape)
        k = k + jnp.broadcast_to(params["bk"].astype(x.dtype), k.shape)
        v = v + jnp.broadcast_to(params["bv"].astype(x.dtype), v.shape)
    return (
        q.reshape(b, s, nh, dh),
        k.reshape(b, s, nkv, dh),
        v.reshape(b, s, nkv, dh),
    )


def _sdpa(q, k, v, *, causal: bool, q_offset=0):
    """q: [b, sq, nh, dh]; k/v: [b, sk, nkv, dh]; GQA via head grouping."""
    b, sq, nh, dh = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qg = q.reshape(b, sq, nkv, group, dh)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh ** -0.5)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, nh, dh)


def _sdpa_chunked(q, k, v, *, causal: bool, kv_block: int,
                  unroll: bool = False, compute_dtype=jnp.float32):
    """Online-softmax attention, scanning KV blocks (flash-attention
    schedule in pure lax): memory is O(sq * kv_block) instead of O(sq * sk).

    This is what makes 32k prefill lowerable at production batch sizes; on
    real TPU the same schedule is the Pallas flash kernel's.
    """
    b, sq, nh, dh = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    nblocks = sk // kv_block
    qg = q.reshape(b, sq, nkv, group, dh).astype(compute_dtype)
    kb = k.reshape(b, nblocks, kv_block, nkv, dh)
    vb = v.reshape(b, nblocks, kv_block, nkv, dh)
    qpos = jnp.arange(sq)[:, None]

    def step(carry, blk):
        acc, m, l = carry
        kblk, vblk, blk_idx = blk
        scores = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kblk.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        ) * (dh ** -0.5)
        if causal:
            kpos = blk_idx * kv_block + jnp.arange(kv_block)[None, :]
            scores = jnp.where((qpos >= kpos)[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(compute_dtype),
            vblk.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, nkv, group, sq, dh), jnp.float32)
    m0 = jnp.full((b, nkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, nkv, group, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step,
        (acc0, m0, l0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.arange(nblocks),
        ),
        unroll=nblocks if unroll else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # [b, nkv, group, sq, dh] -> [b, sq, nh, dh]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, nh, dh)
    return out.astype(q.dtype)


def attention_apply(
    params: Params,
    x: jax.Array,
    cfg: AttentionConfig,
    positions=None,
    kv_block: int | None = None,
    unroll: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Full-sequence (training / prefill) attention.

    ``kv_block`` switches to the online-softmax KV-block scan (required at
    long sequence to avoid materializing [sq, sk] scores).
    """
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_block is not None and s % kv_block == 0 and s > kv_block:
        out = _sdpa_chunked(q, k, v, causal=cfg.causal, kv_block=kv_block,
                            unroll=unroll, compute_dtype=compute_dtype)
    else:
        out = _sdpa(q, k, v, causal=cfg.causal)
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype)


def attention_decode(
    params: Params,
    x: jax.Array,          # [b, 1, d] the new token
    cache_k: jax.Array,    # [b, max_seq, nkv, dh]
    cache_v: jax.Array,
    cache_len: jax.Array,  # int32 scalar: tokens already cached
    cfg: AttentionConfig,
):
    """One-token decode against a KV cache. Returns (out, new_k, new_v)."""
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), cache_len, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), cache_len, axis=1
    )
    # mask out cache slots beyond cache_len (+1 for the new token)
    sk = cache_k.shape[1]
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    group = nh // nkv
    qg = q.reshape(b, 1, nkv, group, dh)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        qg.astype(jnp.float32),
        cache_k.astype(jnp.float32),
    ) * (dh ** -0.5)
    kpos = jnp.arange(sk)[None, None, None, None, :]
    scores = jnp.where(kpos <= cache_len, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", probs.astype(cache_v.dtype), cache_v
    ).reshape(b, 1, nh * dh)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_gated_mlp(key, d: int, d_ff: int, param_dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), param_dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, d_ff), param_dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (d_ff, d), param_dtype) * d_ff ** -0.5,
    }


def gated_mlp(params: Params, x: jax.Array, act=jax.nn.silu) -> jax.Array:
    g = act(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_up"].astype(x.dtype)
    return (g * u) @ params["w_down"].astype(x.dtype)


def init_mlp(key, dims: list[int], param_dtype=jnp.float32,
             bias: bool = True) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, din, dout in zip(keys, dims[:-1], dims[1:]):
        layer = {"w": jax.random.normal(k, (din, dout), param_dtype)
                 * din ** -0.5}
        if bias:
            layer["b"] = jnp.zeros((dout,), param_dtype)
        layers.append(layer)
    return {"layers": layers}


def mlp_apply(params: Params, x: jax.Array, act=jax.nn.relu,
              final_act: bool = False) -> jax.Array:
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + jnp.broadcast_to(layer["b"].astype(x.dtype), x.shape)
        if i < n - 1 or final_act:
            x = act(x)
    return x
