"""Decoder-only transformer LMs (dense / GQA / MoE) with train, prefill and
cached-decode entry points.

Layers are stacked with ``lax.scan`` (params carry a leading layer axis), so
compile time is O(1) in depth — essential for 512-device dry-runs — and the
layer body is rematerialized (activation checkpointing) for training memory.
The LM loss is computed in token chunks so the [tokens, vocab] logits tensor
never materializes at once (vocab stays sharded over the `model` axis; the
chunk loop bounds the transient).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import AttentionConfig
from repro.models.moe import MoEConfig, init_moe, moe_apply

Params = Any


def _checkpoint(body, cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(body)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    # vocab rows are padded so embedding/LM-head shard evenly over `model`
    # (granite's 49155 is not divisible by 16); padded logits are masked
    vocab_pad_multiple: int = 16
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    moe: MoEConfig | None = None
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    attn_kv_block: int = 2048   # online-softmax KV block for seq > block
    loss_chunk: int = 8192      # tokens per logits chunk
    remat: bool = True
    # remat policy: 'full' recomputes everything (min memory, max recompute
    # flops); 'dots' saves matmul outputs (kills the recompute of the whole
    # attention score pipeline at ~2x boundary memory)
    remat_policy: str = "full"
    # dtype of the attention score/PV matmuls (f32 accumulation either way);
    # bf16 halves score-pipeline HBM traffic on TPU
    attn_compute_dtype: str = "float32"
    # Megatron-style sequence parallelism: residual stream sharded over the
    # `model` axis on the sequence dim between blocks; turns activation
    # all-reduces into reduce-scatter/all-gather pairs and divides
    # norm/residual bytes per device by the TP degree
    seq_parallel: bool = False
    dp_axes_for_sp: tuple = ("data",)
    # unroll all depth/microbatch/chunk scans: identical math, no while
    # loops — used by the dry-run so cost_analysis counts every iteration
    # (XLA costs a while body ONCE, not x trip-count)
    unroll_scans: bool = False

    @property
    def attn(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            rope_theta=self.rope_theta,
            qkv_bias=self.qkv_bias,
        )

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        nh, nkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * nh * dh + 2 * d * nkv * dh + nh * dh * d
        if self.moe:
            m = self.moe
            mlp = (
                d * m.n_experts  # router
                + m.n_experts * 3 * d * m.d_ff_expert
                + (3 * d * m.d_ff_shared if m.d_ff_shared else 0)
            )
        else:
            mlp = 3 * d * ff
        return self.n_layers * (attn + mlp + 2 * d) + 2 * v * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d, v, m = self.d_model, self.vocab_size, self.moe
        nh, nkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * nh * dh + 2 * d * nkv * dh + nh * dh * d
        mlp = (
            d * m.n_experts
            + m.top_k * 3 * d * m.d_ff_expert
            + (3 * d * m.d_ff_shared if m.d_ff_shared else 0)
        )
        return self.n_layers * (attn + mlp + 2 * d) + 2 * v * d + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: TransformerConfig):
    pd = jnp.dtype(cfg.param_dtype)
    k_attn, k_mlp = jax.random.split(key)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model, pd),
        "ln2": layers.init_rmsnorm(cfg.d_model, pd),
        "attn": layers.init_attention(k_attn, cfg.attn, pd),
    }
    if cfg.moe:
        p["moe"] = init_moe(k_mlp, cfg.d_model, cfg.moe, pd)
    else:
        p["mlp"] = layers.init_gated_mlp(k_mlp, cfg.d_model, cfg.d_ff, pd)
    return p


def init_transformer(key, cfg: TransformerConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": jax.random.normal(
            k_embed, (cfg.padded_vocab, cfg.d_model), pd
        ) * cfg.d_model ** -0.5,
        "layers": stacked,
        "ln_f": layers.init_rmsnorm(cfg.d_model, pd),
        "lm_head": jax.random.normal(
            k_head, (cfg.d_model, cfg.padded_vocab), pd
        ) * cfg.d_model ** -0.5,
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _sp_constraint(x, cfg):
    from jax.sharding import PartitionSpec as P

    dp = cfg.dp_axes_for_sp
    dp = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, P(dp, "model", None))


def _layer_body(x, layer_params, cfg: TransformerConfig, positions):
    kv_block = cfg.attn_kv_block if x.shape[1] > cfg.attn_kv_block else None
    if cfg.seq_parallel:
        x = _sp_constraint(x, cfg)
    h = x + layers.attention_apply(
        layer_params["attn"],
        layers.rmsnorm(layer_params["ln1"], x, cfg.norm_eps),
        cfg.attn,
        positions,
        kv_block=kv_block,
        unroll=cfg.unroll_scans,
        compute_dtype=jnp.dtype(cfg.attn_compute_dtype),
    )
    if cfg.seq_parallel:
        h = _sp_constraint(h, cfg)
    normed = layers.rmsnorm(layer_params["ln2"], h, cfg.norm_eps)
    if cfg.moe and cfg.moe.expert_shard_map:
        from repro.models.moe import moe_apply_ep

        y, aux = moe_apply_ep(layer_params["moe"], normed, cfg.moe)
    elif cfg.moe:
        y, aux = moe_apply(layer_params["moe"], normed, cfg.moe)
    else:
        y, aux = layers.gated_mlp(layer_params["mlp"], normed), {}
    return h + y, aux


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig):
    """tokens [b, s] -> (hidden [b, s, d], aux). Scan over layers + remat."""
    dt = cfg.compute_dtype
    x = params["embed"].astype(dt)[tokens]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    def body(x, layer_params):
        return _layer_body(x, layer_params, cfg, positions)

    if cfg.remat:
        body = _checkpoint(body, cfg)
    x, aux = jax.lax.scan(body, x, params["layers"],
                          unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    aux = {k: v.mean() for k, v in aux.items()} if aux else {}
    return x, aux


# ---------------------------------------------------------------------------
# loss (chunked over tokens)
# ---------------------------------------------------------------------------
def lm_loss(params: Params, tokens, labels, cfg: TransformerConfig,
            aux_weight: float = 0.01, z_weight: float = 1e-4):
    hidden, aux = forward(params, tokens, cfg)
    b, s, d = hidden.shape
    t = b * s
    h = hidden.reshape(t, d)
    y = labels.reshape(t)
    chunk = min(cfg.loss_chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        h = jnp.concatenate([h, jnp.zeros((pad, d), h.dtype)])
        y = jnp.concatenate([y, jnp.full((pad,), -1, y.dtype)])
    hc = h.reshape(n_chunks, chunk, d)
    yc = y.reshape(n_chunks, chunk)
    head = params["lm_head"]

    vocab_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size

    def chunk_loss(carry, xs):
        hb, yb = xs
        logits = (hb @ head.astype(hb.dtype)).astype(jnp.float32)
        logits = jnp.where(vocab_mask[None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yb, 0)[:, None], axis=-1
        )[:, 0]
        w = (yb >= 0).astype(jnp.float32)
        nll = (lse - gold) * w
        return carry, (nll.sum(), w.sum())

    _, (nll_sums, w_sums) = jax.lax.scan(
        chunk_loss, (), (hc, yc),
        unroll=n_chunks if cfg.unroll_scans else 1,
    )
    loss = nll_sums.sum() / jnp.maximum(w_sums.sum(), 1.0)
    metrics = {"lm_loss": loss, **aux}
    total = loss
    if "load_balance_loss" in aux:
        total = total + aux_weight * aux["load_balance_loss"]
        total = total + z_weight * aux["router_z_loss"]
    return total, metrics


# ---------------------------------------------------------------------------
# serving: prefill + cached decode
# ---------------------------------------------------------------------------
def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig):
    """Run the prompt; return (last-token logits, kv cache, cache_len).

    Cache layout: k/v [n_layers, b, s, n_kv, d_head] (seq dim shardable
    over `model` for long-context decode).
    """
    dt = cfg.compute_dtype
    b, s = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def body(x, layer_params):
        # recompute k/v (cheap relative to attention) to emit the cache
        normed = layers.rmsnorm(layer_params["ln1"], x, cfg.norm_eps)
        _, k, v = layers._qkv(layer_params["attn"], normed, cfg.attn)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        x, _ = _layer_body(x, layer_params, cfg, positions)
        return x, (k, v)

    if cfg.remat:
        body = _checkpoint(body, cfg)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"],
                               unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    logits = jnp.where(
        jnp.arange(cfg.padded_vocab) < cfg.vocab_size, logits, -1e30
    )
    return logits, {"k": ks, "v": vs}, jnp.int32(s)


def decode_step(params: Params, token: jax.Array, cache, cache_len,
                cfg: TransformerConfig):
    """One decode step. token [b, 1] -> (logits, updated cache)."""
    dt = cfg.compute_dtype
    x = params["embed"].astype(dt)[token]

    def body(x, xs):
        layer_params, k_l, v_l = xs
        normed = layers.rmsnorm(layer_params["ln1"], x, cfg.norm_eps)
        attn_out, k_new, v_new = layers.attention_decode(
            layer_params["attn"], normed, k_l, v_l, cache_len, cfg.attn
        )
        h = x + attn_out
        normed2 = layers.rmsnorm(layer_params["ln2"], h, cfg.norm_eps)
        if cfg.moe and cfg.moe.expert_shard_map:
            from repro.models.moe import moe_apply_ep

            y, _ = moe_apply_ep(layer_params["moe"], normed2, cfg.moe)
        elif cfg.moe:
            y, _ = moe_apply(layer_params["moe"], normed2, cfg.moe)
        else:
            y = layers.gated_mlp(layer_params["mlp"], normed2)
        return h + y, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.unroll_scans else 1,
    )
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    logits = jnp.where(
        jnp.arange(cfg.padded_vocab) < cfg.vocab_size, logits, -1e30
    )
    return logits, {"k": ks, "v": vs}


def make_empty_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                     dtype=None):
    dt = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
