"""Architecture substrate: transformers (dense/GQA/MoE), GNNs, recsys."""
