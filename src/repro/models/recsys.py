"""Two-tower retrieval model (Yi et al., RecSys'19 / Covington RecSys'16).

Huge sparse embedding tables -> per-field lookup (single-hot) + history
EmbeddingBag (multi-hot) -> tower MLP -> L2-normalized embeddings -> dot
interaction -> in-batch sampled softmax with logQ correction.

The lookup hot path is the hypersparse plus_times product (EmbeddingBag ==
bags x vocab incidence @ table), implemented on the same segment machinery
as the traffic-matrix builder, with the spmm_coo Pallas kernel available via
``use_kernel``. Tables are row-sharded over the `model` mesh axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Any


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    n_user_fields: int = 8      # single-hot categorical fields
    n_item_fields: int = 8
    history_len: int = 50       # multi-hot user history (item ids)
    user_vocab: int = 10_000_000
    item_vocab: int = 10_000_000
    temperature: float = 0.05
    use_kernel: bool = False
    dtype: str = "float32"

    @property
    def user_tower_in(self) -> int:
        # field embeddings + history bag embedding
        return (self.n_user_fields + 1) * self.embed_dim

    @property
    def item_tower_in(self) -> int:
        return self.n_item_fields * self.embed_dim


def init_two_tower(key, cfg: TwoTowerConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = cfg.embed_dim ** -0.5
    return {
        "user_table": jax.random.normal(
            k1, (cfg.user_vocab, cfg.embed_dim), jnp.float32
        ) * scale,
        "item_table": jax.random.normal(
            k2, (cfg.item_vocab, cfg.embed_dim), jnp.float32
        ) * scale,
        "user_mlp": layers.init_mlp(
            k3, [cfg.user_tower_in, *cfg.tower_mlp]
        ),
        "item_mlp": layers.init_mlp(
            k4, [cfg.item_tower_in, *cfg.tower_mlp]
        ),
    }


def _bag_lookup(table, indices, bag_ids, num_bags, n_valid, use_kernel):
    if use_kernel:
        from repro.kernels.embed_bag import ops as eb_ops

        return eb_ops.embedding_bag(
            table, indices, bag_ids, num_bags=num_bags, n_valid=n_valid,
            mode="mean",
        )
    from repro.kernels.embed_bag.ref import embedding_bag_ref

    return embedding_bag_ref(
        table, indices, bag_ids, num_bags, None, n_valid, mode="mean"
    )


def user_tower(params, user_fields, history, history_len, cfg: TwoTowerConfig):
    """user_fields: int32[b, n_user_fields]; history: int32[b, H] item ids
    (padded); history_len: int32[b]."""
    b = user_fields.shape[0]
    field_emb = params["user_table"][
        jnp.minimum(user_fields, cfg.user_vocab - 1)
    ]  # [b, F, dim]
    h = history.reshape(b * cfg.history_len)
    bag = jnp.repeat(jnp.arange(b, dtype=jnp.int32), cfg.history_len)
    # mask padded history slots by pushing them to an out-of-range bag
    slot = jnp.tile(jnp.arange(cfg.history_len, dtype=jnp.int32), b)
    valid = slot < jnp.repeat(history_len, cfg.history_len)
    bag = jnp.where(valid, bag, b)
    from repro.kernels.embed_bag.ref import embedding_bag_ref

    if cfg.use_kernel:
        from repro.kernels.embed_bag import ops as eb_ops

        hist_emb = eb_ops.embedding_bag(
            params["item_table"], h, bag, num_bags=b, mode="mean"
        )
    else:
        hist_emb = embedding_bag_ref(
            params["item_table"], h, bag, b, None, None, "mean"
        )
    feats = jnp.concatenate(
        [field_emb.reshape(b, -1), hist_emb], axis=-1
    )
    out = layers.mlp_apply(params["user_mlp"], feats, act=jax.nn.relu)
    return out / jnp.maximum(
        jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6
    )


def item_tower(params, item_fields, cfg: TwoTowerConfig):
    """item_fields: int32[b, n_item_fields]."""
    b = item_fields.shape[0]
    emb = params["item_table"][
        jnp.minimum(item_fields, cfg.item_vocab - 1)
    ]
    out = layers.mlp_apply(
        params["item_mlp"], emb.reshape(b, -1), act=jax.nn.relu
    )
    return out / jnp.maximum(
        jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6
    )


def in_batch_softmax_loss(params, batch, cfg: TwoTowerConfig):
    """Sampled softmax with in-batch negatives and logQ correction."""
    u = user_tower(
        params, batch["user_fields"], batch["history"],
        batch["history_len"], cfg,
    )
    v = item_tower(params, batch["item_fields"], cfg)
    logits = (u @ v.T) / cfg.temperature  # [b, b]
    # logQ correction: subtract log sampling probability of each candidate
    logq = batch.get("log_q")
    if logq is not None:
        logits = logits - logq[None, :]
    b = logits.shape[0]
    labels = jnp.arange(b)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).astype(jnp.float32).mean()
    return loss, {"loss": loss, "in_batch_accuracy": acc}


def score_pairs(params, batch, cfg: TwoTowerConfig):
    """Online inference: score one (user, item) pair per row."""
    u = user_tower(
        params, batch["user_fields"], batch["history"],
        batch["history_len"], cfg,
    )
    v = item_tower(params, batch["item_fields"], cfg)
    return jnp.sum(u * v, axis=-1)


def retrieve_topk(params, batch, candidate_fields, cfg: TwoTowerConfig,
                  k: int = 100):
    """One query against n_candidates items: batched dot + top-k."""
    u = user_tower(
        params, batch["user_fields"], batch["history"],
        batch["history_len"], cfg,
    )  # [1, dim]
    v = item_tower(params, candidate_fields, cfg)  # [n_cand, dim]
    scores = (u @ v.T)[0]  # [n_cand]
    return jax.lax.top_k(scores, k)
