"""GNN architectures on edge lists: GCN, GAT, EGNN, PNA.

Message passing is GraphBLAS algebra (SpMM / SDDMM over the adjacency
pattern), and these layers are built directly on the core segment primitives
— the same sort/segment/scatter machinery that builds traffic matrices.
JAX has no CSR/CSC; the edge-index + ``segment_sum`` formulation IS the
system's sparse substrate (with the Pallas spmm_coo/sddmm kernels as the
TPU hot path via ``use_kernel``).

Graphs arrive padded: ``edge_src/edge_dst [E]`` with ``n_edges`` valid,
node features ``x [N, d]`` with ``n_nodes`` valid. Batched small graphs
(molecule shape) are flattened into one padded graph with a ``graph_id``
per node for readout.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Any


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # gcn | gat | egnn | pna
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    n_heads: int = 1           # gat
    aggregators: tuple = ("mean", "max", "min", "std")  # pna
    scalers: tuple = ("identity", "amplification", "attenuation")  # pna
    mean_log_degree: float = 2.0  # pna delta
    use_kernel: bool = False
    dtype: str = "float32"


def _edge_valid(e: int, n_edges) -> jax.Array:
    return jnp.arange(e, dtype=jnp.int32) < n_edges


def _clip(idx, n):
    return jnp.minimum(idx.astype(jnp.int32), n - 1)


def _agg_sum(src_feat, dst, n, valid):
    contrib = jnp.where(valid[:, None], src_feat, 0)
    return jax.ops.segment_sum(contrib, dst, num_segments=n)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------
def init_gcn(key, cfg: GNNConfig) -> Params:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            {
                "w": jax.random.normal(k, (di, do), jnp.float32) * di ** -0.5,
                "b": jnp.zeros((do,), jnp.float32),
            }
            for k, di, do in zip(keys, dims[:-1], dims[1:])
        ]
    }


def gcn_apply(params, x, edge_src, edge_dst, n_nodes, n_edges,
              cfg: GNNConfig):
    n, e = x.shape[0], edge_src.shape[0]
    valid = _edge_valid(e, n_edges)
    src = _clip(edge_src, n)
    dst = _clip(edge_dst, n)
    # symmetric normalization from in-degree (graph is pre-symmetrized
    # with self-loops by the data layer)
    deg = jax.ops.segment_sum(valid.astype(jnp.float32), dst, num_segments=n)
    deg = jnp.maximum(deg, 1.0)
    w_e = jax.lax.rsqrt(deg[src]) * jax.lax.rsqrt(deg[dst])
    w_e = jnp.where(valid, w_e, 0.0)

    h = x
    for i, layer in enumerate(params["layers"]):
        hw = h @ layer["w"]
        if cfg.use_kernel:
            from repro.kernels.spmm_coo import ops as spmm_ops

            agg = spmm_ops.spmm_coo(dst, src, w_e, hw, n_edges, num_rows=n)
        else:
            agg = jax.ops.segment_sum(
                w_e[:, None] * hw[src], dst, num_segments=n
            )
        h = agg + jnp.broadcast_to(layer["b"], agg.shape)
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------
def init_gat(key, cfg: GNNConfig) -> Params:
    dims_in = [cfg.d_in] + [cfg.d_hidden * cfg.n_heads] * (cfg.n_layers - 1)
    dims_out = [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    layer_params = []
    for k, di, do in zip(keys, dims_in, dims_out):
        k1, k2, k3 = jax.random.split(k, 3)
        layer_params.append(
            {
                "w": jax.random.normal(k1, (di, cfg.n_heads, do), jnp.float32)
                * di ** -0.5,
                "a_src": jax.random.normal(k2, (cfg.n_heads, do), jnp.float32)
                * do ** -0.5,
                "a_dst": jax.random.normal(k3, (cfg.n_heads, do), jnp.float32)
                * do ** -0.5,
            }
        )
    return {"layers": layer_params}


def gat_apply(params, x, edge_src, edge_dst, n_nodes, n_edges,
              cfg: GNNConfig):
    n, e = x.shape[0], edge_src.shape[0]
    valid = _edge_valid(e, n_edges)
    src = _clip(edge_src, n)
    dst = _clip(edge_dst, n)
    h = x
    n_layers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        nh, do = layer["a_src"].shape
        hw = jnp.einsum("nd,dhf->nhf", h, layer["w"])  # [n, heads, do]
        s_src = jnp.einsum("nhf,hf->nh", hw, layer["a_src"])
        s_dst = jnp.einsum("nhf,hf->nh", hw, layer["a_dst"])
        scores = jax.nn.leaky_relu(
            s_src[src] + s_dst[dst], negative_slope=0.2
        )  # [e, heads]
        scores = jnp.where(valid[:, None], scores, -1e30)
        smax = jax.ops.segment_max(scores, dst, num_segments=n)
        smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
        ex = jnp.where(valid[:, None], jnp.exp(scores - smax[dst]), 0.0)
        denom = jax.ops.segment_sum(ex, dst, num_segments=n)
        alpha = ex / jnp.maximum(denom[dst], 1e-9)  # [e, heads]
        agg = jax.ops.segment_sum(
            alpha[..., None] * hw[src], dst, num_segments=n
        )  # [n, heads, do]
        if i < n_layers - 1:
            h = jax.nn.elu(agg.reshape(n, nh * do))
        else:
            h = agg.mean(axis=1)  # average heads at the output layer
    return h


# ---------------------------------------------------------------------------
# EGNN (E(n)-equivariant)
# ---------------------------------------------------------------------------
def init_egnn(key, cfg: GNNConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layer_params = []
    for k in keys[: cfg.n_layers]:
        k1, k2, k3 = jax.random.split(k, 3)
        layer_params.append(
            {
                "phi_e": layers.init_mlp(k1, [2 * d + 1, d, d]),
                "phi_x": layers.init_mlp(k2, [d, d, 1]),
                "phi_h": layers.init_mlp(k3, [2 * d, d, d]),
            }
        )
    return {
        "encode": layers.init_mlp(keys[-2], [cfg.d_in, d]),
        "layers": layer_params,
        "decode": layers.init_mlp(keys[-1], [d, d, cfg.n_classes]),
    }


def egnn_apply(params, x, coords, edge_src, edge_dst, n_nodes, n_edges,
               cfg: GNNConfig):
    n, e = x.shape[0], edge_src.shape[0]
    valid = _edge_valid(e, n_edges)
    src = _clip(edge_src, n)
    dst = _clip(edge_dst, n)
    h = layers.mlp_apply(params["encode"], x)
    pos = coords
    for layer in params["layers"]:
        diff = pos[dst] - pos[src]           # [e, 3]
        dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = layers.mlp_apply(
            params_in := layer["phi_e"],
            jnp.concatenate([h[dst], h[src], dist2], axis=-1),
            act=jax.nn.silu, final_act=True,
        )
        m = jnp.where(valid[:, None], m, 0.0)
        # coordinate update (equivariant)
        xw = layers.mlp_apply(layer["phi_x"], m, act=jax.nn.silu)
        deg = jax.ops.segment_sum(
            valid.astype(jnp.float32), dst, num_segments=n
        )
        coord_upd = jax.ops.segment_sum(
            jnp.where(valid[:, None], diff * xw, 0.0), dst, num_segments=n
        ) / jnp.maximum(deg, 1.0)[:, None]
        pos = pos + coord_upd
        # feature update
        m_agg = jax.ops.segment_sum(m, dst, num_segments=n)
        h = h + layers.mlp_apply(
            layer["phi_h"],
            jnp.concatenate([h, m_agg], axis=-1),
            act=jax.nn.silu,
        )
    return layers.mlp_apply(params["decode"], h, act=jax.nn.silu), pos


# ---------------------------------------------------------------------------
# PNA (principal neighbourhood aggregation)
# ---------------------------------------------------------------------------
def init_pna(key, cfg: GNNConfig) -> Params:
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layer_params = []
    for i, k in enumerate(keys[: cfg.n_layers]):
        layer_params.append(
            {"post": layers.init_mlp(k, [(n_agg + 1) * d, d, d])}
        )
    return {
        "encode": layers.init_mlp(keys[-2], [cfg.d_in, d]),
        "layers": layer_params,
        "decode": layers.init_mlp(keys[-1], [d, d, cfg.n_classes]),
    }


def pna_apply(params, x, edge_src, edge_dst, n_nodes, n_edges,
              cfg: GNNConfig):
    n, e = x.shape[0], edge_src.shape[0]
    valid = _edge_valid(e, n_edges)
    src = _clip(edge_src, n)
    dst = _clip(edge_dst, n)
    deg = jax.ops.segment_sum(valid.astype(jnp.float32), dst, num_segments=n)
    degc = jnp.maximum(deg, 1.0)
    log_deg = jnp.log(deg + 1.0)
    delta = cfg.mean_log_degree

    h = layers.mlp_apply(params["encode"], x)
    for layer in params["layers"]:
        msg = jnp.where(valid[:, None], h[src], 0.0)
        s = jax.ops.segment_sum(msg, dst, num_segments=n)
        mean = s / degc[:, None]
        mx = jax.ops.segment_max(
            jnp.where(valid[:, None], h[src], -1e30), dst, num_segments=n
        )
        mx = jnp.where(mx < -1e29, 0.0, mx)
        mn = jax.ops.segment_min(
            jnp.where(valid[:, None], h[src], 1e30), dst, num_segments=n
        )
        mn = jnp.where(mn > 1e29, 0.0, mn)
        sq = jax.ops.segment_sum(msg * msg, dst, num_segments=n)
        var = jnp.maximum(sq / degc[:, None] - mean * mean, 0.0)
        std = jnp.sqrt(var + 1e-5)
        aggs = {"mean": mean, "max": mx, "min": mn, "std": std, "sum": s}
        feats = []
        for agg_name in cfg.aggregators:
            a = aggs[agg_name]
            for scaler in cfg.scalers:
                if scaler == "identity":
                    feats.append(a)
                elif scaler == "amplification":
                    feats.append(a * (log_deg / delta)[:, None])
                elif scaler == "attenuation":
                    feats.append(a * (delta / jnp.maximum(log_deg, 1e-5))[:, None])
        feats.append(h)
        h = layers.mlp_apply(
            layer["post"], jnp.concatenate(feats, axis=-1), act=jax.nn.relu,
            final_act=True,
        )
    return layers.mlp_apply(params["decode"], h, act=jax.nn.relu)


# ---------------------------------------------------------------------------
# unified entry points
# ---------------------------------------------------------------------------
def init_gnn(key, cfg: GNNConfig) -> Params:
    return {
        "gcn": init_gcn, "gat": init_gat, "egnn": init_egnn, "pna": init_pna
    }[cfg.arch](key, cfg)


def gnn_forward(params, batch, cfg: GNNConfig):
    """batch: dict with x, edge_src, edge_dst, n_nodes, n_edges
    (+ coords for egnn). Returns node-level outputs [N, n_classes]."""
    args = (
        batch["x"], batch["edge_src"], batch["edge_dst"],
        batch["n_nodes"], batch["n_edges"],
    )
    if cfg.arch == "gcn":
        return gcn_apply(params, *args, cfg)
    if cfg.arch == "gat":
        return gat_apply(params, *args, cfg)
    if cfg.arch == "egnn":
        out, _ = egnn_apply(
            params, batch["x"], batch["coords"], batch["edge_src"],
            batch["edge_dst"], batch["n_nodes"], batch["n_edges"], cfg
        )
        return out
    if cfg.arch == "pna":
        return pna_apply(params, *args, cfg)
    raise ValueError(cfg.arch)


def node_classification_loss(params, batch, cfg: GNNConfig):
    """Masked cross-entropy over labeled nodes."""
    logits = gnn_forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch["label_mask"].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[:, None], axis=-1
    )[:, 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    acc = (
        ((logits.argmax(-1) == labels).astype(jnp.float32) * mask).sum()
        / jnp.maximum(mask.sum(), 1.0)
    )
    return loss, {"loss": loss, "accuracy": acc}


def graph_classification_loss(params, batch, cfg: GNNConfig):
    """Readout (mean over graph_id) + cross-entropy; molecule shape."""
    node_out = gnn_forward(params, batch, cfg).astype(jnp.float32)
    n = node_out.shape[0]
    gid = batch["graph_id"].astype(jnp.int32)
    n_graphs = batch["graph_labels"].shape[0]
    node_valid = (jnp.arange(n, dtype=jnp.int32) < batch["n_nodes"]).astype(
        jnp.float32
    )
    summed = jax.ops.segment_sum(
        node_out * node_valid[:, None], gid, num_segments=n_graphs
    )
    counts = jax.ops.segment_sum(node_valid, gid, num_segments=n_graphs)
    pooled = summed / jnp.maximum(counts, 1.0)[:, None]
    logp = jax.nn.log_softmax(pooled, axis=-1)
    labels = batch["graph_labels"]
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[:, None], axis=-1
    )[:, 0]
    loss = nll.mean()
    acc = (pooled.argmax(-1) == labels).astype(jnp.float32).mean()
    return loss, {"loss": loss, "accuracy": acc}
