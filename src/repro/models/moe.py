"""Mixture-of-Experts layer with sort-based dispatch.

The token->expert dispatch is a hypersparse incidence problem (tokens x
experts, k entries per token), and we route it with exactly the machinery of
the paper's matrix builder: stable sort by expert id, run-rank within runs,
capacity-bounded scatter into dense per-expert buffers, grouped GEMM, then a
segment-sum combine. No [T, E, C] one-hot dispatch tensors are ever
materialized — at production token counts those don't fit HBM, while the
sort-based path is O(T*k) memory, the same reason the paper's DPU pipeline
sorts packets instead of densifying 2^32-wide rows.

Expert-parallel sharding: the expert axis of the buffers/weights shards over
the ``model`` mesh axis (all-to-all inserted by SPMD at the buffer
boundary); experts are padded up to a multiple of the axis size.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    d_ff_shared: int = 0  # 0 = no shared expert
    capacity_factor: float = 1.25
    norm_topk: bool = True  # qwen-style renormalized top-k gates
    n_experts_padded: int | None = None  # pad for expert-parallel divisibility
    # expert-parallel shard_map path (moe_apply_ep): dispatch locally per
    # shard (activations are model-replicated under Megatron TP, so every
    # shard routes identically and just slices its own experts), combine
    # with one psum. Avoids XLA's global-sort all-gather of dispatch
    # buffers, which replicates O(T*k*d) bytes per device at 32k prefill.
    expert_shard_map: bool = False
    model_axis: str = "model"
    dp_axes: tuple = ("data",)

    @property
    def e_padded(self) -> int:
        return self.n_experts_padded or self.n_experts


def init_moe(key, d_model: int, cfg: MoEConfig, param_dtype=jnp.float32):
    k_router, k_e, k_s = jax.random.split(key, 3)
    e, ff = cfg.e_padded, cfg.d_ff_expert
    scale_d = d_model ** -0.5
    scale_f = ff ** -0.5
    ks = jax.random.split(k_e, 3)
    params = {
        "router": jax.random.normal(k_router, (d_model, cfg.n_experts),
                                    param_dtype) * scale_d,
        "w_gate": jax.random.normal(ks[0], (e, d_model, ff), param_dtype)
        * scale_d,
        "w_up": jax.random.normal(ks[1], (e, d_model, ff), param_dtype)
        * scale_d,
        "w_down": jax.random.normal(ks[2], (e, ff, d_model), param_dtype)
        * scale_f,
    }
    if cfg.d_ff_shared:
        params["shared"] = layers.init_gated_mlp(
            k_s, d_model, cfg.d_ff_shared, param_dtype
        )
    return params


def expert_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def moe_apply(params, x: jax.Array, cfg: MoEConfig):
    """x: [b, s, d] -> (out [b, s, d], aux losses dict)."""
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    e = cfg.e_padded
    cap = expert_capacity(t, cfg)

    # --- routing -----------------------------------------------------------
    logits = (tokens @ params["router"].astype(tokens.dtype)).astype(
        jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)  # [t, k]
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )

    # --- sort-based dispatch (the GrB build primitive) ----------------------
    n_pairs = t * cfg.top_k
    expert_of_pair = gate_idx.reshape(n_pairs)
    token_of_pair = jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)
    gate_of_pair = gate_vals.reshape(n_pairs)

    order = jnp.argsort(expert_of_pair, stable=True)
    sorted_expert = expert_of_pair[order]
    # rank within each expert run
    iota = jnp.arange(n_pairs, dtype=jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_expert[1:] != sorted_expert[:-1]]
    )
    run_start = jax.lax.cummax(jnp.where(first, iota, 0), axis=0)
    rank = iota - run_start

    keep = rank < cap
    slot = jnp.where(keep, sorted_expert * cap + rank, e * cap)

    sorted_token = token_of_pair[order]
    buffer = jnp.zeros((e * cap, d), tokens.dtype)
    buffer = buffer.at[slot].set(tokens[sorted_token], mode="drop")

    # --- grouped expert GEMMs (expert axis shards over `model`) ------------
    h = buffer.reshape(e, cap, d)
    g = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", h, params["w_gate"].astype(h.dtype))
    )
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"].astype(h.dtype))
    y = jnp.einsum(
        "ecf,efd->ecd", g * u, params["w_down"].astype(h.dtype)
    ).reshape(e * cap, d)

    # --- combine ------------------------------------------------------------
    out_pair = jnp.where(
        keep[:, None],
        y[jnp.minimum(slot, e * cap - 1)],
        jnp.zeros((1, d), y.dtype),
    )
    weighted = out_pair * gate_of_pair[order][:, None].astype(y.dtype)
    combined = jax.ops.segment_sum(weighted, sorted_token, num_segments=t)

    if cfg.d_ff_shared:
        combined = combined + layers.gated_mlp(params["shared"], tokens)

    # --- aux losses ----------------------------------------------------------
    # Switch-style load balance: E * sum_e f_e * p_e
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], cfg.n_experts,
                                  dtype=jnp.float32)
    f = one_hot_top1.mean(axis=0)
    p = probs.mean(axis=0)
    aux = {
        "load_balance_loss": cfg.n_experts * jnp.sum(f * p),
        "router_z_loss": jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2
        ),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return combined.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------
def _moe_local(x_loc, router, w_gate, w_up, w_down, shared, cfg: MoEConfig):
    """Per-shard body: x_loc [t_loc, d] (replicated over model axis);
    w_* are this shard's expert slices [e_loc, ...]."""
    t, d = x_loc.shape
    e = cfg.e_padded
    e_loc = w_gate.shape[0]
    m = e // e_loc
    mi = jax.lax.axis_index(cfg.model_axis)
    cap = expert_capacity(t, cfg)

    logits = (x_loc @ router.astype(x_loc.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )

    n_pairs = t * cfg.top_k
    expert_of_pair = gate_idx.reshape(n_pairs)
    token_of_pair = jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)
    gate_of_pair = gate_vals.reshape(n_pairs)

    order = jnp.argsort(expert_of_pair, stable=True)
    sorted_expert = expert_of_pair[order]
    iota = jnp.arange(n_pairs, dtype=jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_expert[1:] != sorted_expert[:-1]]
    )
    run_start = jax.lax.cummax(jnp.where(first, iota, 0), axis=0)
    rank = iota - run_start

    # only this shard's experts get buffered: zero-communication dispatch
    local_expert = sorted_expert - mi * e_loc
    is_mine = (local_expert >= 0) & (local_expert < e_loc)
    keep = is_mine & (rank < cap)
    slot = jnp.where(keep, local_expert * cap + rank, e_loc * cap)
    sorted_token = token_of_pair[order]
    buffer = jnp.zeros((e_loc * cap, d), x_loc.dtype)
    buffer = buffer.at[slot].set(x_loc[sorted_token], mode="drop")

    h = buffer.reshape(e_loc, cap, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate.astype(h.dtype)))
    u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(h.dtype))
    y = jnp.einsum(
        "ecf,efd->ecd", g * u, w_down.astype(h.dtype)
    ).reshape(e_loc * cap, d)

    out_pair = jnp.where(
        keep[:, None],
        y[jnp.minimum(slot, e_loc * cap - 1)],
        jnp.zeros((1, d), y.dtype),
    )
    weighted = out_pair * gate_of_pair[order][:, None].astype(y.dtype)
    combined = jax.ops.segment_sum(weighted, sorted_token, num_segments=t)
    # each token's experts are spread across shards: one all-reduce combines
    combined = jax.lax.psum(combined, cfg.model_axis)

    if cfg.d_ff_shared:
        # shared expert: column-parallel over the model axis, local partial
        gs = jax.nn.silu(x_loc @ shared["w_gate"].astype(x_loc.dtype))
        us = x_loc @ shared["w_up"].astype(x_loc.dtype)
        partial = (gs * us) @ shared["w_down"].astype(x_loc.dtype)
        combined = combined + jax.lax.psum(partial, cfg.model_axis)

    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], cfg.n_experts,
                                  dtype=jnp.float32)
    aux = {
        "load_balance_loss": cfg.n_experts * jnp.sum(
            one_hot_top1.mean(0) * probs.mean(0)
        ),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
        "dropped_fraction": 1.0 - (rank < cap).mean(),
    }
    # aux values are identical across model shards (same routing); average
    # over data shards happens in the caller's metrics reduction
    aux = {k: jax.lax.pmean(v, cfg.dp_axes) for k, v in aux.items()}
    return combined, aux


def moe_apply_ep(params, x: jax.Array, cfg: MoEConfig):
    """shard_map expert-parallel MoE: x [b, s, d] -> (out, aux).

    Requires an ambient mesh (``launch.mesh.ambient_mesh``) whose axes
    include cfg.model_axis and cfg.dp_axes. Parameters must be sharded with
    `transformer_param_rules` (experts over `model`; shared expert
    column-parallel).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    b, s, d = x.shape
    dp = cfg.dp_axes if len(cfg.dp_axes) > 1 else cfg.dp_axes[0]
    shared = params.get("shared", {
        "w_gate": jnp.zeros((d, 0), x.dtype),
        "w_up": jnp.zeros((d, 0), x.dtype),
        "w_down": jnp.zeros((0, d), x.dtype),
    })
    shared_specs = {"w_gate": P(None, cfg.model_axis),
                    "w_up": P(None, cfg.model_axis),
                    "w_down": P(cfg.model_axis, None)}

    def body(xf, router, wg, wu, wd, sh):
        return _moe_local(xf, router, wg, wu, wd, sh, cfg)

    out, aux = shard_map(
        body,
        in_specs=(
            P(dp, None),                       # x tokens
            P(),                               # router
            P(cfg.model_axis, None, None),     # w_gate
            P(cfg.model_axis, None, None),     # w_up
            P(cfg.model_axis, None, None),     # w_down
            shared_specs,
        ),
        out_specs=(P(dp, None), {k: P() for k in (
            "load_balance_loss", "router_z_loss", "dropped_fraction")}),
        check_rep=False,
    )(
        x.reshape(b * s, d), params["router"], params["w_gate"],
        params["w_up"], params["w_down"], shared,
    )
    return out.reshape(b, s, d), aux
