"""phi3.5-moe-42b-a6.6b: 32L d_model=4096 32H (GQA kv=8) MoE 16 experts
top-2 (d_ff_expert=6400), vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct]"""

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "transformer"
SHAPES = tuple(base.LM_SHAPES)


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=6400,
        vocab_size=32064,
        rope_theta=10000.0,
        moe=MoEConfig(
            n_experts=16,
            top_k=2,
            d_ff_expert=6400,
            d_ff_shared=0,
            norm_topk=False,   # phi/mixtral-style softmax-over-topk
        ),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=128, vocab_size=512, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, d_ff_shared=0,
                      norm_topk=False),
    )


def build_cell(shape_name, mesh, costing=False, costing_layers=None):
    return base.lm_build_cell(model_config(), shape_name, mesh,
                              mb_per_device=1, costing=costing,
                              costing_layers=costing_layers)


def smoke():
    return base.lm_smoke(smoke_config(), ARCH_ID)
