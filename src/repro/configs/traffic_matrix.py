"""traffic-matrix: the paper's own workload as a first-class config.

Distributed ingest: a global batch of traffic windows (2^17 packets each,
the paper's window size) is sharded one-window-per-device across the whole
mesh; each device anonymizes + builds its hypersparse matrix and computes
window analytics; global statistics reduce over the mesh with monoid
collectives (psum/pmax — GraphBLAS reductions distributed).

Baseline global analytics are exact for packet counts / maxima / histograms;
device-local unique counts are summed (an upper bound — exact distinct
counts need the cross-device merge, which is the §Perf hillclimb for this
cell, see launch/ingest.py).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.core import analytics
from repro.core.window import WindowConfig, merge_tree, process_windows_batched
from repro.distributed import sharding as shrules

ARCH_ID = "traffic-matrix"
FAMILY = "traffic"
SHAPES = ("ingest_512w", "ingest_analytics", "ingest_exact", "ingest_flow")

PAPER_WINDOW = 1 << 17


def window_config(window_log2: int = 17) -> WindowConfig:
    return WindowConfig(window_log2=window_log2, windows_per_batch=64,
                        anonymization="feistel")


def flow_window_config(window_log2: int | None = None) -> WindowConfig:
    """Geometry for the Suricata-flow workload (records, not packets) —
    flow feeds are pre-aggregated ~100x below the packet rate.  The
    canonical defaults live with the CLI (launch.ingest.GEOMETRY_DEFAULTS)
    so the dry-run cell and the launcher cannot drift apart."""
    from repro.launch.ingest import GEOMETRY_DEFAULTS

    geom = GEOMETRY_DEFAULTS["flow"]
    return WindowConfig(
        window_log2=window_log2 or geom["window_log2"],
        windows_per_batch=geom["windows_per_batch"],
        anonymization="feistel",
    )


_SUM_KEYS = ("valid_packets", "unique_links", "unique_sources",
             "unique_destinations")
_MAX_KEYS = ("max_packets_per_link", "max_source_packets",
             "max_source_fanout", "max_dest_packets", "max_dest_fanin")
_HIST_KEYS = ("src_packet_hist", "dst_packet_hist", "src_fanout_hist",
              "dst_fanin_hist")


def device_ingest(windows_local: jax.Array, cfg: WindowConfig,
                  with_analytics: bool = True):
    """Per-device work: [w_local, n, 2] uint32 -> (stats, merged matrix)."""
    mats = process_windows_batched(windows_local, cfg)
    if windows_local.shape[0] == 1:
        merged = jax.tree.map(lambda a: a[0], mats)
        ovf = jnp.int32(0)
    else:
        merged, ovf = merge_tree(mats, cfg)
    if not with_analytics:
        return {"nnz": merged.nnz, "overflow": ovf}, merged
    stats = analytics.window_stats(merged)
    stats["merge_overflow"] = ovf
    return stats, merged


def make_ingest_step(mesh, cfg: WindowConfig, *, windows_per_device: int = 1,
                     with_analytics: bool = True):
    axes = shrules.all_axes(mesh)
    flat = axes if len(axes) > 1 else axes[0]

    def shard_fn(windows_local):
        stats, merged = device_ingest(windows_local, cfg, with_analytics)
        out = {}
        for k, v in stats.items():
            if k in _MAX_KEYS:
                out[k] = jax.lax.pmax(v, axes)
            else:  # sums, hists, counters
                out[k] = jax.lax.psum(v, axes)
        return out

    return shrules.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=P(flat),
        out_specs=P(),
        check_rep=False,
    )


def build_cell(shape_name, mesh, costing=False):
    del costing  # no scans (merge tree is a python loop)
    flow = shape_name == "ingest_flow"
    cfg = flow_window_config() if flow else window_config()
    n_dev = mesh.size
    wpd = 1
    record_width = 2
    if shape_name in ("ingest_exact", "ingest_flow"):
        # beyond-baseline: exact global merge via row-block all_to_all;
        # the flow shape routes value payloads through the same exchange
        from repro.launch.ingest import make_exact_ingest_step

        step = make_exact_ingest_step(
            mesh, cfg, workload="flow" if flow else "packets"
        )
        if flow:
            record_width = 5
    else:
        with_analytics = shape_name == "ingest_analytics"
        step = make_ingest_step(mesh, cfg, windows_per_device=wpd,
                                with_analytics=with_analytics)
    windows = base.sds((n_dev * wpd, cfg.window_size, record_width),
                       jnp.uint32)
    axes = shrules.all_axes(mesh)
    flat = axes if len(axes) > 1 else axes[0]
    # flops: sort is compare-bound; count the useful arithmetic: anonymize
    # (~40 int ops/addr) + segment ops ~ O(n log n) compares
    n_pkts = n_dev * wpd * cfg.window_size
    flops = n_pkts * (2 * 40 + 2 * cfg.window_log2)
    note = ("one 2^13-flow window per device (value-payload build)"
            if flow else
            "one 2^17-packet window per device (paper's per-core unit)")
    return base.Cell(
        arch_id=ARCH_ID, shape_name=shape_name, fn=step,
        args=(windows,), in_specs=(P(flat),), out_specs=None,
        kind="serve", model_flops_per_step=flops,
        note=note,
    )


def smoke():
    cfg = WindowConfig(window_log2=8, windows_per_batch=4,
                       cap_max_log2=11, anonymization="feistel")
    key = jax.random.PRNGKey(0)
    windows = jax.random.randint(
        key, (4, cfg.window_size, 2), 0, 1 << 30, dtype=jnp.int32
    ).astype(jnp.uint32)

    def fn(state, batch):
        stats, merged = device_ingest(batch, cfg)
        return stats

    def check(stats):
        assert int(stats["valid_packets"]) == 4 * cfg.window_size
        assert int(stats["unique_links"]) > 0
        for k in _HIST_KEYS:
            assert stats[k].shape == (analytics.HIST_BINS,)

    return base.SmokeCase(ARCH_ID, fn, None, windows, check)
