"""two-tower-retrieval: embed_dim=256, tower MLP 1024-512-256, dot
interaction, sampled softmax with logQ. [Yi et al. RecSys'19 (YouTube)]"""

from repro.configs import base
from repro.models.recsys import TwoTowerConfig

ARCH_ID = "two-tower-retrieval"
FAMILY = "recsys"
SHAPES = tuple(base.RECSYS_SHAPES)


def model_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name=ARCH_ID,
        embed_dim=256,
        tower_mlp=(1024, 512, 256),
        n_user_fields=8,
        n_item_fields=8,
        history_len=50,
        user_vocab=10_000_000,
        item_vocab=10_000_000,
    )


def smoke_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name=ARCH_ID + "-smoke", embed_dim=16, tower_mlp=(64, 32),
        n_user_fields=3, n_item_fields=2, history_len=5,
        user_vocab=1000, item_vocab=1000,
    )


def build_cell(shape_name, mesh, costing=False):
    del costing  # no scans
    return base.recsys_build_cell(model_config(), ARCH_ID, shape_name, mesh)


def smoke():
    return base.recsys_smoke(smoke_config(), ARCH_ID)
