"""egnn: 4 layers, d_hidden=64, E(n)-equivariant. [arXiv:2102.09844]"""

from repro.configs import base
from repro.models.gnn import GNNConfig

ARCH_ID = "egnn"
FAMILY = "gnn"
SHAPES = tuple(base.GNN_SHAPES)


def make_cfg(shape: dict) -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID, arch="egnn", n_layers=4, d_in=shape["d_feat"],
        d_hidden=64, n_classes=shape["n_classes"],
    )


def build_cell(shape_name, mesh, costing=False):
    del costing  # no scans: the production program is the costing program
    return base.gnn_build_cell(make_cfg, ARCH_ID, shape_name, mesh)


def smoke():
    return base.gnn_smoke(make_cfg, ARCH_ID)
