"""gcn-cora: 2 layers, d_hidden=16, mean aggregator, symmetric norm.
[arXiv:1609.02907]"""

from repro.configs import base
from repro.models.gnn import GNNConfig

ARCH_ID = "gcn-cora"
FAMILY = "gnn"
SHAPES = tuple(base.GNN_SHAPES)


def make_cfg(shape: dict) -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID, arch="gcn", n_layers=2, d_in=shape["d_feat"],
        d_hidden=16, n_classes=shape["n_classes"],
    )


def build_cell(shape_name, mesh, costing=False):
    del costing  # no scans: the production program is the costing program
    return base.gnn_build_cell(make_cfg, ARCH_ID, shape_name, mesh)


def smoke():
    return base.gnn_smoke(make_cfg, ARCH_ID)
