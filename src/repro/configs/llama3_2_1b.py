"""llama3.2-1b: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "llama3.2-1b"
FAMILY = "transformer"
SHAPES = tuple(base.LM_SHAPES)


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_head=64,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=256, vocab_size=512,
        rope_theta=500000.0, dtype="float32",
    )


def build_cell(shape_name, mesh, costing=False, costing_layers=None):
    return base.lm_build_cell(model_config(), shape_name, mesh,
                              mb_per_device=2, costing=costing,
                              costing_layers=costing_layers)


def smoke():
    return base.lm_smoke(smoke_config(), ARCH_ID)
