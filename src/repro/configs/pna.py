"""pna: 4 layers, d_hidden=75, aggregators mean/max/min/std, scalers
identity/amplification/attenuation. [arXiv:2004.05718]"""

from repro.configs import base
from repro.models.gnn import GNNConfig

ARCH_ID = "pna"
FAMILY = "gnn"
SHAPES = tuple(base.GNN_SHAPES)


def make_cfg(shape: dict) -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID, arch="pna", n_layers=4, d_in=shape["d_feat"],
        d_hidden=75, n_classes=shape["n_classes"],
        aggregators=("mean", "max", "min", "std"),
        scalers=("identity", "amplification", "attenuation"),
    )


def build_cell(shape_name, mesh, costing=False):
    del costing  # no scans: the production program is the costing program
    return base.gnn_build_cell(make_cfg, ARCH_ID, shape_name, mesh)


def smoke():
    return base.gnn_smoke(make_cfg, ARCH_ID)
