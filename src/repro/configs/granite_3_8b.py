"""granite-3-8b: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-*-base family; hf]"""

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "granite-3-8b"
FAMILY = "transformer"
SHAPES = tuple(base.LM_SHAPES)


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=10000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab_size=512, dtype="float32",
    )


def build_cell(shape_name, mesh, costing=False, costing_layers=None):
    # largest dense arch: deeper microbatching to bound remat residuals
    return base.lm_build_cell(model_config(), shape_name, mesh,
                              mb_per_device=1, costing=costing,
                              costing_layers=costing_layers)


def smoke():
    return base.lm_smoke(smoke_config(), ARCH_ID)
