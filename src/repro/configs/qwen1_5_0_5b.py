"""qwen1.5-0.5b: 24L d_model=1024 16H (kv=16, MHA) d_ff=2816 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen1.5-0.5b"
FAMILY = "transformer"
SHAPES = tuple(base.LM_SHAPES)


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1000000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab_size=512, qkv_bias=True,
        dtype="float32",
    )


def build_cell(shape_name, mesh, costing=False, costing_layers=None):
    return base.lm_build_cell(model_config(), shape_name, mesh,
                              mb_per_device=8, costing=costing,
                              costing_layers=costing_layers)


def smoke():
    return base.lm_smoke(smoke_config(), ARCH_ID)
