"""Config system: arch registry, dry-run cell builders, smoke configs.

Every assigned architecture is a module exposing:
  ARCH_ID, FAMILY, SHAPES (the assignment's input-shape set),
  build_cell(shape_name, mesh) -> Cell   (abstract args for lower/compile)
  smoke() -> SmokeCase                   (tiny concrete fwd/train step)

A ``Cell`` is everything ``launch.dryrun`` needs: the step callable, abstract
arguments (ShapeDtypeStruct — nothing is allocated), and the in/out
PartitionSpecs for the production mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shrules
from repro.models import transformer as tfm
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.optim import adamw
from repro.optim.grad import clip_by_global_norm


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable                      # step function to lower
    args: tuple                       # pytree of ShapeDtypeStruct
    in_specs: tuple                   # matching PartitionSpecs
    out_specs: Any = None             # None = auto
    kind: str = "train"               # train | prefill | decode | serve
    note: str = ""
    model_flops_per_step: float = 0.0  # 6*N*D (dense) / 6*N_active*D (MoE)
    # costing cells lower a reduced-batch unrolled variant; multiply its
    # HLO flops/bytes/collectives by cost_scale to get full-step numbers
    cost_scale: float = 1.0


@dataclasses.dataclass
class SmokeCase:
    arch_id: str
    fn: Callable          # (state_or_params, batch) -> outputs
    state: Any            # concrete small state
    batch: Any            # concrete small batch
    check: Callable       # outputs -> None (asserts)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def abstract_tree(f, *args, **kwargs):
    """jax.eval_shape -> pytree of ShapeDtypeStruct (no allocation)."""
    return jax.eval_shape(functools.partial(f, **kwargs), *args)


# ---------------------------------------------------------------------------
# generic transformer cells
# ---------------------------------------------------------------------------
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# microbatch counts for train_4k, tuned to keep remat boundaries in HBM
LM_MICROBATCH = {"default": 4}


def make_lm_train_step(cfg: tfm.TransformerConfig, n_micro: int,
                       learning_rate: float = 3e-4,
                       grad_reduce_dtype: str | None = None):
    """Microbatched, gradient-accumulated, clipped AdamW train step.

    grad_reduce_dtype='bfloat16' casts the locally-accumulated (f32)
    gradients before the cross-data all-reduce, halving the DP collective
    bytes (standard practice; accumulation itself stays f32).
    """
    opt = adamw()

    def loss_fn(params, tokens, labels):
        return tfm.lm_loss(params, tokens, labels, cfg)

    def step(state, batch):
        params, opt_state = state["params"], state["opt"]
        tokens, labels = batch["tokens"], batch["labels"]
        b = tokens.shape[0]
        mb = b // n_micro
        tkm = tokens.reshape(n_micro, mb, -1)
        lbm = labels.reshape(n_micro, mb, -1)

        def micro(acc, xs):
            tk, lb = xs
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, tk, lb)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return acc, loss

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        grads, losses = jax.lax.scan(
            micro, zeros, (tkm, lbm),
            unroll=n_micro if cfg.unroll_scans else 1,
        )
        if grad_reduce_dtype is not None:
            rd = jnp.dtype(grad_reduce_dtype)
            grads = jax.tree.map(
                lambda g: g.astype(rd).astype(jnp.float32), grads
            )
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt.update(grads, params, opt_state,
                                         jnp.float32(learning_rate))
        return (
            {"params": new_params, "opt": new_opt},
            {"loss": losses.mean(), "grad_norm": gnorm},
        )

    return step, opt


def _lm_state_abstract(cfg: tfm.TransformerConfig):
    opt = adamw()
    key = jax.random.PRNGKey(0)
    params = abstract_tree(tfm.init_transformer, key, cfg=cfg)
    opt_state = jax.eval_shape(opt.init, params)
    return {"params": params, "opt": opt_state}


def _lm_state_specs(cfg, state, mesh, zero1=True, replicate_kv=False):
    pspecs = shrules.param_specs(state["params"], "transformer",
                                 replicate_kv=replicate_kv)
    ospecs = shrules.opt_state_specs(
        pspecs, state["opt"], zero1=zero1, mesh=mesh, params=state["params"]
    )
    return {"params": pspecs, "opt": ospecs}


def lm_build_cell(cfg: tfm.TransformerConfig, shape_name: str, mesh: Mesh,
                  *, mb_per_device: int = 2, costing: bool = False,
                  costing_layers: int | None = None,
                  replicate_kv: bool = False,
                  grad_reduce_dtype: str | None = None) -> Cell:
    sh = LM_SHAPES[shape_name]
    seq, gb = sh["seq_len"], sh["global_batch"]
    kind = sh["kind"]
    tokens_per_step = gb * seq
    # microbatch count chosen so the per-device microbatch (and with it the
    # remat-boundary memory) is constant across mesh sizes
    dp_size = 1
    for a in shrules.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    n_micro = max(1, gb // (dp_size * mb_per_device))
    cost_scale = 1.0
    if costing:
        # reduced-batch (one microbatch), fully unrolled variant: XLA costs
        # while bodies once, so the costing program must have no loops.
        # costing_layers (1 or 2) lets the runner lower two shallow
        # variants and extrapolate affinely in depth — per-step cost is
        # exactly a + b*L for a homogeneous layer stack, and compile time
        # stays O(1) in depth (an unrolled 32-layer MoE does not compile
        # in reasonable time at 512 devices).
        cfg = dataclasses.replace(cfg, unroll_scans=True)
        if costing_layers is not None:
            cfg = dataclasses.replace(cfg, n_layers=costing_layers)
        if kind == "train":
            gb = gb // n_micro
            cost_scale = float(n_micro)
            n_micro = 1
    dp = shrules.batch_axes_for(gb, mesh)

    if kind == "train":
        step, _ = make_lm_train_step(cfg, n_micro,
                                     grad_reduce_dtype=grad_reduce_dtype)
        state = _lm_state_abstract(cfg)
        state_specs = _lm_state_specs(cfg, state, mesh,
                                      replicate_kv=replicate_kv)
        batch = {
            "tokens": sds((gb, seq), jnp.int32),
            "labels": sds((gb, seq), jnp.int32),
        }
        batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        flops = 6.0 * cfg.active_param_count() * tokens_per_step
        return Cell(
            arch_id=cfg.name, shape_name=shape_name, fn=step,
            args=(state, batch), in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, {"loss": P(), "grad_norm": P()}),
            kind=kind, model_flops_per_step=flops, cost_scale=cost_scale,
        )

    params = abstract_tree(
        tfm.init_transformer, jax.random.PRNGKey(0), cfg=cfg
    )
    pspecs = shrules.param_specs(params, "transformer",
                                 replicate_kv=replicate_kv)

    if kind == "prefill":
        def prefill_fn(params, tokens):
            return tfm.prefill(params, tokens, cfg)

        batch = sds((gb, seq), jnp.int32)
        cache_spec = P(None, dp, "model", None, None)
        out_specs = (
            P(dp, "model"),                    # logits (vocab-sharded)
            {"k": cache_spec, "v": cache_spec},
            P(),                                # cache_len
        )
        # prefill = forward only: 2*N*D
        flops = 2.0 * cfg.active_param_count() * tokens_per_step
        return Cell(
            arch_id=cfg.name, shape_name=shape_name, fn=prefill_fn,
            args=(params, batch), in_specs=(pspecs, P(dp, None)),
            out_specs=out_specs, kind=kind, model_flops_per_step=flops,
        )

    # decode kinds: one new token against a seq_len cache
    def decode_fn(params, token, cache, cache_len):
        return tfm.decode_step(params, token, cache, cache_len, cfg)

    cache_shape = (cfg.n_layers, gb, seq, cfg.n_kv_heads, cfg.d_head)
    cache = {
        "k": sds(cache_shape, cfg.compute_dtype),
        "v": sds(cache_shape, cfg.compute_dtype),
    }
    cache_spec = P(None, dp, "model", None, None)
    cache_specs = {"k": cache_spec, "v": cache_spec}
    token = sds((gb, 1), jnp.int32)
    # decode flops: 2*N_active per token (+ attention reads over cache)
    flops = 2.0 * cfg.active_param_count() * gb
    return Cell(
        arch_id=cfg.name, shape_name=shape_name, fn=decode_fn,
        args=(params, token, cache, sds((), jnp.int32)),
        in_specs=(pspecs, P(dp, None), cache_specs, P()),
        out_specs=(P(dp, None), cache_specs),
        kind="decode", model_flops_per_step=flops,
        note="full-attention arch: 500k runs decode (linear/step), "
             "not quadratic prefill" if shape_name == "long_500k" else "",
    )


def lm_smoke(cfg_small: tfm.TransformerConfig, arch_id: str) -> SmokeCase:
    key = jax.random.PRNGKey(0)
    params = tfm.init_transformer(key, cfg_small)
    step, opt = make_lm_train_step(cfg_small, n_micro=2)
    state = {"params": params, "opt": opt.init(params)}
    tokens = jax.random.randint(key, (4, 32), 0, cfg_small.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    def check(out):
        import numpy as np

        new_state, metrics = out
        assert np.isfinite(float(metrics["loss"])), metrics
        assert np.isfinite(float(metrics["grad_norm"]))
        leaf = jax.tree.leaves(new_state["params"])[0]
        assert np.isfinite(np.asarray(leaf)).all()

    return SmokeCase(arch_id, step, state, batch, check)


# ---------------------------------------------------------------------------
# generic GNN cells
# ---------------------------------------------------------------------------
def _pad512(n: int) -> int:
    return -(-n // 512) * 512


GNN_SHAPES = {
    "full_graph_sm": dict(
        n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7,
        task="node", pad_edges=_pad512(10556),
    ),
    "minibatch_lg": dict(
        # sampled subgraph for batch_nodes=1024, fanout 15-10 over the
        # 233k-node / 115M-edge graph (Reddit-scale): layered node counts
        n_nodes=1024 + 1024 * 15 + 1024 * 150, d_feat=602, n_classes=41,
        n_edges=1024 * 15 + 15360 * 10, task="node_targets",
        n_targets=1024, pad_edges=_pad512(1024 * 15 + 15360 * 10),
    ),
    "ogb_products": dict(
        n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47,
        task="node", pad_edges=_pad512(61_859_140),
    ),
    "molecule": dict(
        n_nodes=128 * 30, n_edges=128 * 64, d_feat=32, n_classes=8,
        task="graph", n_graphs=128, pad_edges=_pad512(128 * 64),
    ),
}


def make_gnn_train_step(cfg: gnn_mod.GNNConfig, task: str,
                        learning_rate: float = 1e-3):
    opt = adamw(weight_decay=0.0)

    def loss_fn(params, batch):
        if task == "graph":
            return gnn_mod.graph_classification_loss(params, batch, cfg)
        return gnn_mod.node_classification_loss(params, batch, cfg)

    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt.update(
            grads, state["params"], state["opt"], jnp.float32(learning_rate)
        )
        return {"params": new_params, "opt": new_opt}, {
            **metrics, "grad_norm": gnorm
        }

    return step, opt


def gnn_batch_abstract(shape: dict, with_coords: bool):
    n, e = shape["n_nodes"], shape["pad_edges"]
    batch = {
        "x": sds((n, shape["d_feat"]), jnp.float32),
        "edge_src": sds((e,), jnp.int32),
        "edge_dst": sds((e,), jnp.int32),
        "n_nodes": sds((), jnp.int32),
        "n_edges": sds((), jnp.int32),
        "labels": sds((n,), jnp.int32),
        "label_mask": sds((n,), jnp.int32),
    }
    if with_coords:
        batch["coords"] = sds((n, 3), jnp.float32)
    if shape["task"] == "graph":
        batch["graph_id"] = sds((n,), jnp.int32)
        batch["graph_labels"] = sds((shape["n_graphs"],), jnp.int32)
    return batch


def gnn_batch_specs(batch: dict, mesh: Mesh):
    """Edges sharded across the whole machine; node arrays replicated."""
    edge_axes = shrules.all_axes(mesh)
    specs = {k: P() for k in batch}
    specs["edge_src"] = P(edge_axes)
    specs["edge_dst"] = P(edge_axes)
    return specs


def gnn_build_cell(make_cfg, arch_id: str, shape_name: str,
                   mesh: Mesh) -> Cell:
    shape = GNN_SHAPES[shape_name]
    cfg = make_cfg(shape)
    task = shape["task"]
    if task == "node_targets":
        task = "node"  # loss masks to targets via label_mask
    step, opt = make_gnn_train_step(cfg, task)
    key = jax.random.PRNGKey(0)
    params = abstract_tree(gnn_mod.init_gnn, key, cfg=cfg)
    opt_state = jax.eval_shape(opt.init, params)
    state = {"params": params, "opt": opt_state}
    pspecs = shrules.param_specs(params, "gnn")
    ospecs = shrules.opt_state_specs(pspecs, state["opt"])
    batch = gnn_batch_abstract(shape, with_coords=cfg.arch == "egnn")
    bspecs = gnn_batch_specs(batch, mesh)
    # per-edge gather-multiply-scatter ~ 2 flops per feature per layer
    flops = 2.0 * shape["n_edges"] * cfg.d_hidden * cfg.n_layers * 3
    return Cell(
        arch_id=arch_id, shape_name=shape_name, fn=step,
        args=(state, batch),
        in_specs=({"params": pspecs, "opt": ospecs}, bspecs),
        kind="train", model_flops_per_step=flops,
    )


def gnn_smoke(make_cfg, arch_id: str) -> SmokeCase:
    from repro.data.graphs import random_graph

    shape = dict(n_nodes=64, n_edges=256, d_feat=16, n_classes=4,
                 task="node", pad_edges=512)
    cfg = make_cfg(shape)
    g = random_graph(0, n_nodes=64, n_edges=200, d_feat=16, n_classes=4,
                     pad_edges=512, with_coords=True)
    batch = {k: jnp.asarray(v) for k, v in g.batch_dict().items()}
    step, opt = make_gnn_train_step(cfg, "node")
    params = gnn_mod.init_gnn(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": opt.init(params)}

    def check(out):
        import numpy as np

        _, metrics = out
        assert np.isfinite(float(metrics["loss"]))

    return SmokeCase(arch_id, step, state, batch, check)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------
RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000,
                           pad_candidates=_pad512(1_000_000)),
}


def make_recsys_train_step(cfg: rec_mod.TwoTowerConfig,
                           learning_rate: float = 1e-3):
    opt = adamw(weight_decay=0.0)

    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: rec_mod.in_batch_softmax_loss(p, batch, cfg),
            has_aux=True,
        )(state["params"])
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt.update(
            grads, state["params"], state["opt"], jnp.float32(learning_rate)
        )
        return {"params": new_params, "opt": new_opt}, {
            **metrics, "grad_norm": gnorm
        }

    return step, opt


def _recsys_batch_abstract(cfg: rec_mod.TwoTowerConfig, b: int,
                           with_items=True, with_logq=False):
    batch = {
        "user_fields": sds((b, cfg.n_user_fields), jnp.int32),
        "history": sds((b, cfg.history_len), jnp.int32),
        "history_len": sds((b,), jnp.int32),
    }
    if with_items:
        batch["item_fields"] = sds((b, cfg.n_item_fields), jnp.int32)
    if with_logq:
        batch["log_q"] = sds((b,), jnp.float32)
    return batch


def recsys_build_cell(cfg: rec_mod.TwoTowerConfig, arch_id: str,
                      shape_name: str, mesh: Mesh) -> Cell:
    shape = RECSYS_SHAPES[shape_name]
    kind = shape["kind"]
    b = shape["batch"]
    dp = shrules.batch_axes_for(b, mesh)
    key = jax.random.PRNGKey(0)
    params = abstract_tree(rec_mod.init_two_tower, key, cfg=cfg)
    pspecs = shrules.param_specs(params, "recsys")
    d = cfg.embed_dim
    mlp_flops = 2 * sum(
        a * c for a, c in zip(
            (cfg.user_tower_in,) + cfg.tower_mlp[:-1], cfg.tower_mlp
        )
    ) + 2 * sum(
        a * c for a, c in zip(
            (cfg.item_tower_in,) + cfg.tower_mlp[:-1], cfg.tower_mlp
        )
    )

    if kind == "train":
        step, opt = make_recsys_train_step(cfg)
        opt_state = jax.eval_shape(opt.init, params)
        state = {"params": params, "opt": opt_state}
        ospecs = shrules.opt_state_specs(pspecs, opt_state)
        batch = _recsys_batch_abstract(cfg, b, with_logq=True)
        bspecs = {k: P(dp, *([None] * (len(v.shape) - 1)))
                  for k, v in batch.items()}
        flops = 3 * (b * mlp_flops + 2 * b * b * cfg.tower_mlp[-1])
        return Cell(
            arch_id=arch_id, shape_name=shape_name, fn=step,
            args=(state, batch),
            in_specs=({"params": pspecs, "opt": ospecs}, bspecs),
            kind="train", model_flops_per_step=flops,
        )

    if kind == "serve":
        def serve_fn(params, batch):
            return rec_mod.score_pairs(params, batch, cfg)

        batch = _recsys_batch_abstract(cfg, b)
        bspecs = {k: P(dp, *([None] * (len(v.shape) - 1)))
                  for k, v in batch.items()}
        flops = b * mlp_flops
        return Cell(
            arch_id=arch_id, shape_name=shape_name, fn=serve_fn,
            args=(params, batch), in_specs=(pspecs, bspecs),
            out_specs=P(dp), kind="serve", model_flops_per_step=flops,
        )

    # retrieval: 1 query vs 1M candidates
    nc = shape["pad_candidates"]
    cand_axes = shrules.all_axes(mesh)

    def retrieval_fn(params, batch, cand_fields):
        return rec_mod.retrieve_topk(params, batch, cand_fields, cfg, k=128)

    batch = _recsys_batch_abstract(cfg, 1, with_items=False)
    bspecs = {k: P() for k in batch}
    cands = sds((nc, cfg.n_item_fields), jnp.int32)
    flops = nc * (mlp_flops / 2 + 2 * cfg.tower_mlp[-1])
    return Cell(
        arch_id=arch_id, shape_name=shape_name, fn=retrieval_fn,
        args=(params, batch, cands),
        in_specs=(pspecs, bspecs, P(cand_axes, None)),
        out_specs=None, kind="retrieval",
        model_flops_per_step=flops,
    )


def recsys_smoke(cfg_small: rec_mod.TwoTowerConfig,
                 arch_id: str) -> SmokeCase:
    key = jax.random.PRNGKey(0)
    params = rec_mod.init_two_tower(key, cfg_small)
    step, opt = make_recsys_train_step(cfg_small)
    state = {"params": params, "opt": opt.init(params)}
    b = 16
    ks = jax.random.split(key, 4)
    batch = {
        "user_fields": jax.random.randint(
            ks[0], (b, cfg_small.n_user_fields), 0, cfg_small.user_vocab
        ),
        "history": jax.random.randint(
            ks[1], (b, cfg_small.history_len), 0, cfg_small.item_vocab
        ),
        "history_len": jnp.full((b,), cfg_small.history_len, jnp.int32),
        "item_fields": jax.random.randint(
            ks[2], (b, cfg_small.n_item_fields), 0, cfg_small.item_vocab
        ),
        "log_q": jnp.zeros((b,), jnp.float32),
    }

    def check(out):
        import numpy as np

        _, metrics = out
        assert np.isfinite(float(metrics["loss"]))

    return SmokeCase(arch_id, step, state, batch, check)
