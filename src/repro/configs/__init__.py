"""Architecture registry: the 10 assigned archs + the paper's own workload."""

from repro.configs import (
    egnn,
    gat_cora,
    gcn_cora,
    granite_3_8b,
    llama3_2_1b,
    phi3_5_moe,
    pna,
    qwen1_5_0_5b,
    qwen2_moe_a2_7b,
    traffic_matrix,
    two_tower,
)

ARCHS = {
    m.ARCH_ID: m
    for m in (
        llama3_2_1b,
        granite_3_8b,
        qwen1_5_0_5b,
        qwen2_moe_a2_7b,
        phi3_5_moe,
        gat_cora,
        gcn_cora,
        egnn,
        pna,
        two_tower,
        traffic_matrix,
    )
}

ASSIGNED = [a for a in ARCHS if a != "traffic-matrix"]


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}"
        )
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair in the assignment + paper cells."""
    out = []
    for arch_id, mod in ARCHS.items():
        for shape in mod.SHAPES:
            out.append((arch_id, shape))
    return out
