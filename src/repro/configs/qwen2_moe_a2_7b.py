"""qwen2-moe-a2.7b: 24L d_model=2048 16H (kv=16) MoE 60 experts top-4
(d_ff_expert=1408) + shared expert (5632 = 4x1408), vocab=151936, QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Experts padded 60 -> 64 for 16-way expert parallelism.
"""

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen2-moe-a2.7b"
FAMILY = "transformer"
SHAPES = tuple(base.LM_SHAPES)


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=5632,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1000000.0,
        moe=MoEConfig(
            n_experts=60,
            top_k=4,
            d_ff_expert=1408,
            d_ff_shared=5632,
            norm_topk=True,
            n_experts_padded=64,
        ),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab_size=512, qkv_bias=True,
        dtype="float32",
        moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=32, d_ff_shared=128,
                      n_experts_padded=8),
    )


def build_cell(shape_name, mesh, costing=False, costing_layers=None):
    return base.lm_build_cell(model_config(), shape_name, mesh,
                              mb_per_device=2, costing=costing,
                              costing_layers=costing_layers)


def smoke():
    return base.lm_smoke(smoke_config(), ARCH_ID)
