"""Fault tolerance control plane: heartbeats, straggler policy, elastic
re-meshing.

On a real multi-pod deployment these hooks sit in the launcher process group
(one agent per host). The *policy logic* is hardware-independent and fully
tested here:

  * ``HeartbeatMonitor`` tracks per-host step completion times and flags
    hosts whose step latency exceeds ``threshold x`` the rolling median
    (classic straggler detection);
  * ``StragglerPolicy`` decides: tolerate / drop-contribution (the step
    proceeds with the straggler's microbatch dropped and gradients rescaled
    by the surviving fraction) / evict (trigger elastic re-mesh);
  * ``plan_mesh`` re-plans the (pod, data, model) mesh after losing hosts —
    model parallelism is pinned (params must fit), data parallelism shrinks;
    paired with the topology-free checkpoints this is the elastic-restart
    path: detect -> re-plan -> restore -> continue.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Iterable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_step: int = -1
    last_beat: float = 0.0
    registered_at: float = 0.0
    step_times: list = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, window: int = 16,
                 straggle_factor: float = 3.0, dead_after_s: float = 60.0,
                 now: float | None = None):
        registered = now if now is not None else time.monotonic()
        self.hosts = {h: HostState(h, registered_at=registered)
                      for h in range(n_hosts)}
        self.window = window
        self.straggle_factor = straggle_factor
        self.dead_after_s = dead_after_s

    def beat(self, host_id: int, step: int, step_time_s: float,
             now: float | None = None) -> None:
        h = self.hosts[host_id]
        h.last_step = step
        h.last_beat = now if now is not None else time.monotonic()
        h.step_times.append(step_time_s)
        if len(h.step_times) > self.window:
            h.step_times.pop(0)

    def median_step_time(self) -> float:
        times = [
            statistics.median(h.step_times)
            for h in self.hosts.values()
            if h.alive and h.step_times
        ]
        return statistics.median(times) if times else 0.0

    def stragglers(self) -> list[int]:
        med = self.median_step_time()
        if med <= 0:
            return []
        out = []
        for h in self.hosts.values():
            if h.alive and h.step_times:
                if statistics.median(h.step_times) > self.straggle_factor * med:
                    out.append(h.host_id)
        return out

    def dead(self, now: float | None = None) -> list[int]:
        """Hosts silent for longer than ``dead_after_s``.

        A host that registered but never beat counts its silence from its
        registration timestamp — previously such a host had
        ``last_beat == 0`` and could never be declared dead, which meant a
        worker wedged before its first heartbeat was invisible to the
        straggler policy forever.
        """
        now = now if now is not None else time.monotonic()
        out = []
        for h in self.hosts.values():
            if not h.alive:
                continue
            since = h.last_beat if h.last_beat > 0 else h.registered_at
            if now - since > self.dead_after_s:
                out.append(h.host_id)
        return out

    def mark_dead(self, host_id: int) -> None:
        self.hosts[host_id].alive = False

    def alive_hosts(self) -> list[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    action: str            # "proceed" | "drop" | "evict"
    hosts: tuple = ()
    grad_rescale: float = 1.0


class StragglerPolicy:
    """Deadline-based mitigation: tolerate brief lag, drop persistent
    stragglers' contributions (rescaling gradients), evict dead hosts."""

    def __init__(self, monitor: HeartbeatMonitor,
                 drop_after_straggles: int = 3):
        self.monitor = monitor
        self.drop_after = drop_after_straggles
        self._counts: dict[int, int] = {}

    def evaluate(self, now: float | None = None) -> PolicyDecision:
        dead = self.monitor.dead(now)
        if dead:
            return PolicyDecision("evict", tuple(dead))
        stragglers = self.monitor.stragglers()
        persistent = []
        for h in list(self._counts):
            if h not in stragglers:
                self._counts[h] = 0
        for h in stragglers:
            self._counts[h] = self._counts.get(h, 0) + 1
            if self._counts[h] >= self.drop_after:
                persistent.append(h)
        if persistent:
            n = len(self.monitor.alive_hosts())
            surviving = max(n - len(persistent), 1)
            return PolicyDecision(
                "drop", tuple(persistent), grad_rescale=n / surviving
            )
        return PolicyDecision("proceed")


def plan_mesh(
    n_devices: int,
    *,
    model_parallel: int = 16,
    devices_per_pod: int = 256,
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest usable (pod, data, model) mesh for a device count.

    Model parallelism is pinned (parameter shards must fit); whole pods are
    used when possible; leftover devices idle (reported by caller).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"need at least model_parallel={model_parallel} devices, "
            f"got {n_devices}"
        )
    pods = n_devices // devices_per_pod
    if pods >= 2:
        data = devices_per_pod // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    data = n_devices // model_parallel
    return (data, model_parallel), ("data", "model")


def elastic_transition(
    current_devices: Iterable[int],
    failed: Iterable[int],
    *,
    model_parallel: int = 16,
    devices_per_pod: int = 256,
):
    """Devices after failure -> new mesh plan + devices left idle."""
    remaining = sorted(set(current_devices) - set(failed))
    shape, axes = plan_mesh(
        len(remaining),
        model_parallel=model_parallel,
        devices_per_pod=devices_per_pod,
    )
    used = 1
    for s in shape:
        used *= s
    return {
        "devices": remaining[:used],
        "idle": remaining[used:],
        "mesh_shape": shape,
        "mesh_axes": axes,
    }
