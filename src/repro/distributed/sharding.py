"""Sharding rules: parameter and batch PartitionSpecs per arch family.

Path-pattern rules map parameter pytree paths to PartitionSpecs given the
mesh's axis names, implementing:
  * Megatron-style tensor parallelism over `model` for transformer QKV/O and
    MLP up/down, vocab-sharded embedding + LM head;
  * expert parallelism over `model` for MoE expert weights;
  * row-sharded embedding tables over `model` for recsys;
  * replicated (tiny) GNN parameters with edge-sharded batches;
  * data parallelism over `pod` x `data` for every batch-like axis.

Optimizer state inherits parameter specs; ``zero1_specs`` additionally
shards replicated-state dims over `data` (ZeRO-1).
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


def mesh_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes used for data parallelism (pod + data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes_for(batch_size: int, mesh: Mesh):
    """Largest prefix-combination of dp axes that divides batch_size."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        size = mesh.shape[a]
        if batch_size % (prod * size) == 0:
            axes.append(a)
            prod *= size
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def shard_map(f, *, mesh=None, in_specs, out_specs,
              check_rep: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes top-level ``jax.shard_map`` with ``check_vma``; older
    releases only have ``jax.experimental.shard_map`` with ``check_rep``.
    ``mesh=None`` means "use the ambient mesh" (``jax.set_mesh`` on newer
    jax, the ``with mesh:`` thread resources on older).  Every shard_map in
    this repo goes through here so version drift is handled once.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(
            f, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_rep, **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_rep,
    )


# ---------------------------------------------------------------------------
# rule engine
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def specs_from_rules(params: Params, rules: list[tuple[str, P]]) -> Params:
    """Per-leaf PartitionSpec from the first matching path regex."""
    compiled = [(re.compile(pat), spec) for pat, spec in rules]

    def pick(path, leaf):
        s = _path_str(path)
        for pat, spec in compiled:
            if pat.search(s):
                if len(spec) > leaf.ndim:
                    raise ValueError(
                        f"spec {spec} has more axes than leaf {s} "
                        f"{leaf.shape}"
                    )
                return spec
        return P()

    return jax.tree_util.tree_map_with_path(pick, params)


# ---------------------------------------------------------------------------
# per-family parameter rules  (mesh must have a `model` axis)
# ---------------------------------------------------------------------------
def transformer_param_rules(*, replicate_kv: bool = False
                            ) -> list[tuple[str, P]]:
    # stacked layers carry a leading layer axis (lax.scan over depth)
    #
    # replicate_kv: GQA-aware TP. When n_kv_heads < TP size, sharding the
    # K/V projections forces a (kv_heads, d_head) split that SPMD can only
    # reshard by full rematerialization (observed on granite/llama GQA at
    # TP=16). Replicating the small K/V projections removes every resulting
    # collective-permute/all-gather; Q/O stay fully sharded.
    kv_spec = P() if replicate_kv else P(None, None, "model")
    kv_bias = P() if replicate_kv else P(None, "model")
    return [
        (r"layers/attn/w[kv]$", kv_spec),
        (r"layers/attn/wq$", P(None, None, "model")),
        (r"layers/attn/wo$", P(None, "model", None)),
        (r"layers/attn/b[kv]$", kv_bias),
        (r"layers/attn/bq$", P(None, "model")),
        (r"layers/moe/router$", P()),
        (r"layers/moe/w_(gate|up)$", P(None, "model", None, None)),
        (r"layers/moe/w_down$", P(None, "model", None, None)),
        (r"layers/moe/shared/w_(gate|up)$", P(None, None, "model")),
        (r"layers/moe/shared/w_down$", P(None, "model", None)),
        (r"layers/mlp/w_(gate|up)$", P(None, None, "model")),
        (r"layers/mlp/w_down$", P(None, "model", None)),
        (r"^embed$", P("model", None)),
        (r"^lm_head$", P(None, "model")),
        # norms and everything else: replicated
    ]


def recsys_param_rules(**_) -> list[tuple[str, P]]:
    return [
        (r"(user|item)_table$", P("model", None)),
        # tower MLPs are small: replicate
    ]


def gnn_param_rules(**_) -> list[tuple[str, P]]:
    return []  # tiny params, fully replicated


def param_specs(params: Params, family: str, **opts) -> Params:
    rules = {
        "transformer": transformer_param_rules,
        "recsys": recsys_param_rules,
        "gnn": gnn_param_rules,
        "traffic": gnn_param_rules,  # no params
    }[family](**opts)
    return specs_from_rules(params, rules)


def named_shardings(specs: Params, mesh: Mesh) -> Params:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# optimizer state
# ---------------------------------------------------------------------------
def opt_state_specs(params_specs: Params, opt_state, *,
                    zero1: bool = False, mesh: Mesh | None = None,
                    params: Params | None = None):
    """Moments inherit param specs; optionally ZeRO-1 shard over `data`."""

    def moment_specs():
        if not zero1:
            return params_specs
        assert mesh is not None and params is not None
        dsize = mesh.shape.get("data", 1)

        def shard_more(spec, p):
            if spec and spec[0] is not None:
                return spec  # already sharded on dim 0 (TP)
            if p.ndim >= 1 and p.shape[0] % dsize == 0 and dsize > 1:
                rest = tuple(spec[1:]) if spec else (None,) * (p.ndim - 1)
                return P("data", *rest)
            return spec

        return jax.tree.map(
            shard_more, params_specs, params,
            is_leaf=lambda x: isinstance(x, P),
        )

    ms = moment_specs()
    from repro.optim.optimizers import OptState

    return OptState(
        step=P(),
        mu=ms,
        nu=ms if opt_state.nu is not None else None,
    )
