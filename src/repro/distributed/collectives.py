"""Collective helpers for shard_map code paths.

``compressed_psum`` implements the int8 error-feedback gradient reduction
for the slow cross-pod (DCN) axis: payloads cross the wire as int8
(+ one fp32 scale per tensor), a 4x byte reduction against fp32 all-reduce
on small pod counts, dequantized and summed locally.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.grad import int8_compress

Params = Any


def psum_tree(tree: Params, axis_name: str) -> Params:
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def pmean_tree(tree: Params, axis_name: str) -> Params:
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """all-reduce(x) with int8 on-the-wire payload (all-gather + local sum).

    Exact for the quantized values; pair with error feedback
    (optim.grad.error_feedback_compress) to keep training unbiased.
    """
    q, scale = int8_compress(x)
    qg = jax.lax.all_gather(q, axis_name)          # [N, ...] int8
    sg = jax.lax.all_gather(scale, axis_name)      # [N] fp32
    deq = qg.astype(jnp.float32) * sg.reshape(
        (-1,) + (1,) * (qg.ndim - 1)
    )
    return deq.sum(axis=0).astype(x.dtype)


def compressed_psum_tree(tree: Params, axis_name: str) -> Params:
    return jax.tree.map(lambda x: compressed_psum(x, axis_name), tree)


def reduce_scatter_mean(x: jax.Array, axis_name: str,
                        scatter_dim: int = 0) -> jax.Array:
    n = jax.lax.psum(1, axis_name)
    return jax.lax.psum_scatter(
        x, axis_name, scatter_dimension=scatter_dim, tiled=True
    ) / n
