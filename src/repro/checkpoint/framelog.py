"""Length-prefixed frames of portable pytrees, over files and sockets.

One wire shape for everything that leaves the process: serve-protocol
messages, exporter records, and quarantine dead-letter entries are all
``(kind, tree)`` frames where ``tree`` is encoded with the portable
type-tagged pytree encoding from :mod:`repro.checkpoint.serialization`.

Frame layout (file and socket identical)::

    b"RPFR" | kind:u8 | length:u32be | payload[length]

``FrameLog`` is the file-backed form: an append-only journal with an
explicit byte cursor so crash/resume can truncate back to the last
checkpointed offset and re-append deterministically (no duplicates, no
clobbering — see QuarantineSink / ExporterSink).

File objects opened here register with :func:`track_file` so the test
suite's fd-leak fixture can assert every tracked handle is closed when
a run (including a *failed* run) finishes.
"""

from __future__ import annotations

import io
import socket
import struct
import threading
import weakref
from pathlib import Path

from .serialization import dumps_tree, loads_tree

FRAME_MAGIC = b"RPFR"
_HEADER = struct.Struct(">4sBI")  # magic, kind, payload length

# Registry of live tracked file handles (test-suite fd hygiene). WeakSet:
# a handle that is garbage-collected no longer counts as open, but the
# fixture snapshots live handles so a leaked-and-still-referenced handle
# (sink kept alive by a report/test local) is caught.
_TRACKED: weakref.WeakSet = weakref.WeakSet()
_TRACKED_LOCK = threading.Lock()


def track_file(fh):
    """Register a file object for the fd-leak fixture; returns it."""
    with _TRACKED_LOCK:
        _TRACKED.add(fh)
    return fh


def open_tracked_files() -> list:
    """All tracked file objects that are still open."""
    with _TRACKED_LOCK:
        return [fh for fh in _TRACKED if not fh.closed]


def pack_frame(kind: int, tree) -> bytes:
    payload = dumps_tree(tree)
    return _HEADER.pack(FRAME_MAGIC, kind, len(payload)) + payload


def _read_exact(read, n: int) -> bytes | None:
    """Read exactly n bytes via ``read`` callable; None on clean EOF at
    offset 0 of the request, error on mid-frame EOF."""
    buf = b""
    while len(buf) < n:
        chunk = read(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise EOFError(f"truncated frame: wanted {n} bytes, got {len(buf)}")
        buf += chunk
    return buf


def read_frame(read) -> tuple[int, object] | None:
    """Read one frame via a ``read(n) -> bytes`` callable (file.read or
    socket-recv adapter). Returns (kind, tree) or None on clean EOF."""
    header = _read_exact(read, _HEADER.size)
    if header is None:
        return None
    magic, kind, length = _HEADER.unpack(header)
    if magic != FRAME_MAGIC:
        raise ValueError(f"bad frame magic {magic!r}")
    payload = _read_exact(read, length)
    if payload is None:
        raise EOFError("truncated frame payload")
    return kind, loads_tree(payload)


class FrameLog:
    """Append-only file of frames with an explicit byte cursor.

    - ``append`` is the only write path; the handle is opened lazily in
      append mode, so constructing a FrameLog never clobbers an
      existing file.
    - ``tell()`` reports the durable end offset — checkpoint it, then on
      resume call ``truncate_to(saved)`` to discard frames written after
      the checkpoint; replay re-appends them bit-identically.
    - ``close()`` is idempotent and safe from failure paths.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._fh = None
        self._pos = self.path.stat().st_size if self.path.exists() else 0
        self._lock = threading.Lock()

    def _ensure_open(self):
        if self._fh is None or self._fh.closed:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = track_file(open(self.path, "ab"))
            self._pos = self._fh.tell()

    def tell(self) -> int:
        with self._lock:
            return self._pos

    def append(self, kind: int, tree) -> int:
        """Append one frame; returns the new end offset."""
        frame = pack_frame(kind, tree)
        with self._lock:
            self._ensure_open()
            self._fh.write(frame)
            self._fh.flush()
            self._pos += len(frame)
            return self._pos

    def truncate_to(self, offset: int) -> None:
        """Drop everything after ``offset`` (a value from ``tell()``).

        Resume path: frames appended after the restored checkpoint was
        taken are discarded so the replayed batches re-append without
        duplicates. Never extends the file; raises if the file is
        shorter than the cursor (the journal was clobbered externally).
        """
        with self._lock:
            if self._fh is not None and not self._fh.closed:
                self._fh.close()
                self._fh = None
            size = self.path.stat().st_size if self.path.exists() else 0
            if size < offset:
                raise ValueError(
                    f"frame log {self.path} is {size} bytes, shorter than "
                    f"resume cursor {offset}: refusing to resume against a "
                    "truncated/clobbered journal"
                )
            if size > offset:
                with open(self.path, "r+b") as fh:
                    fh.truncate(offset)
            self._pos = offset

    def close(self) -> None:
        with self._lock:
            if self._fh is not None and not self._fh.closed:
                self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    @staticmethod
    def read_all(path: str | Path) -> list[tuple[int, object]]:
        """Decode every frame in a log file."""
        out = []
        p = Path(path)
        if not p.exists():
            return out
        with open(p, "rb") as fh:
            while True:
                frame = read_frame(fh.read)
                if frame is None:
                    return out
                out.append(frame)


class SocketFrameIO:
    """Frame read/write over a connected socket."""

    def __init__(self, sock):
        self.sock = sock
        self._rfile = sock.makefile("rb")

    def send(self, kind: int, tree) -> None:
        self.sock.sendall(pack_frame(kind, tree))

    def recv(self) -> tuple[int, object] | None:
        return read_frame(self._rfile.read)

    def close(self) -> None:
        # shutdown() before close(): a plain close does not wake another
        # thread blocked in recv() on this socket, SHUT_RDWR does
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:  # repro-lint: disable=swallowed-exception
            pass  # already torn down by the peer; closing is best-effort
        try:
            self._rfile.close()
        except OSError:  # repro-lint: disable=swallowed-exception
            pass
        try:
            self.sock.close()
        except OSError:  # repro-lint: disable=swallowed-exception
            pass


def frames_to_buffer(frames) -> bytes:
    """Pack (kind, tree) pairs into one bytes blob (tests/tools)."""
    buf = io.BytesIO()
    for kind, tree in frames:
        buf.write(pack_frame(kind, tree))
    return buf.getvalue()
