"""Checkpoint manager: atomic writes, keep-N retention, async save thread,
restart discovery.

Fault-tolerance contract for 1000+ node runs:
  * writes are atomic (tmp file + rename), so a node dying mid-save never
    corrupts the latest checkpoint;
  * ``save_async`` hands the host copy to a background thread so the train
    loop is blocked only for device->host transfer, not disk/compression;
  * checkpoints embed step, config fingerprint and the data-iterator state,
    so restart resumes the exact batch stream;
  * restore is topology-free (see serialization.py) — an elastic restart
    onto a different mesh re-shards on device_put.
"""

from __future__ import annotations

import json
import re
import threading
from pathlib import Path
from typing import Any

from repro.checkpoint import serialization

_CKPT_RE = re.compile(r"ckpt_(\d+)\.rpck$")


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # RLock: save() always takes it (it mutates the directory and runs
        # gc), and callers that already hold it (none in-repo, but external
        # code following the old save_async pattern) must not deadlock.
        self._lock = threading.RLock()
        # Serializes the save_async/wait handoff: without it, two threads
        # calling save_async concurrently could both join the old worker,
        # then overwrite _pending with each other's thread — the loser's
        # writer would never be joined (leaked repro-* thread) and its
        # failure never re-raised.
        self._async_lock = threading.Lock()
        self._pending: threading.Thread | None = None
        self._pending_error: BaseException | None = None
        self._clean_stale_tmp()

    def _clean_stale_tmp(self) -> None:
        """Drop leftovers of saves that died between write and rename.

        Only files matching our own tmp naming are touched; a fresh manager
        pointed at a directory with a crashed sibling's half-written
        ``ckpt_*.tmp`` would otherwise carry the garbage forever (``steps()``
        ignores it, but it pins disk and confuses humans).
        """
        for p in self.dir.glob("ckpt_*.tmp"):
            try:
                p.unlink()
            except FileNotFoundError:
                pass

    # -- discovery -----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = _CKPT_RE.match(p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:010d}.rpck"

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, *, meta: dict | None = None,
             portable: bool = False) -> Path:
        # The lock lives here, not in save_async's worker: a direct save()
        # racing an in-flight async save used to mutate/gc the directory
        # unguarded while the worker held _lock.
        with self._lock:
            meta = dict(meta or {})
            meta["step"] = step
            final = self._path(step)
            tmp = final.with_suffix(".tmp")
            serialization.save_pytree(state, tmp, meta=meta,
                                      portable=portable)
            tmp.rename(final)  # atomic on POSIX
            self._gc()
            return final

    def save_async(self, step: int, state: Any, *,
                   meta: dict | None = None, portable: bool = False) -> None:
        """Host-fetch now (cheap), serialize/compress/write in background."""
        import jax

        host_state = jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") else x, state
        )

        def work():
            try:
                self.save(step, host_state, meta=meta, portable=portable)
            except BaseException as exc:  # noqa: BLE001 - re-raised at wait()
                self._pending_error = exc

        with self._async_lock:
            self._wait_pending()  # one in flight at a time; raises prior error
            self._pending = threading.Thread(
                target=work, daemon=True, name=f"repro-ckpt-writer-{step}"
            )
            self._pending.start()

    def wait(self) -> None:
        """Join the in-flight async save, re-raising its exception if it
        failed — a daemon that never observes a failed save would happily
        run forever with no durable checkpoints."""
        with self._async_lock:
            self._wait_pending()

    def _wait_pending(self) -> None:
        # caller holds _async_lock
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        err, self._pending_error = self._pending_error, None
        if err is not None:
            raise err

    # -- restore ---------------------------------------------------------------
    def restore(self, like: Any, step: int | None = None):
        """Returns (state, meta) or (None, None) if no checkpoint exists."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        return serialization.load_pytree(self._path(step), like=like)

    # -- retention ---------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            try:
                self._path(s).unlink()
            except FileNotFoundError:
                pass

    # -- bookkeeping sidecar -------------------------------------------------
    def write_meta(self, name: str, payload: dict) -> None:
        (self.dir / name).write_text(json.dumps(payload, indent=2))
