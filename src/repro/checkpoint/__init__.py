"""Fault-tolerant checkpointing: serialization + atomic keep-N manager."""

from repro.checkpoint.serialization import (  # noqa: F401
    load_pytree,
    save_pytree,
)
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
