"""Pytree <-> bytes: msgpack framing + zstd-compressed raw tensor payloads.

Arrays are fetched to host (fully replicated view) and stored as raw bytes
with dtype/shape metadata; restore rebuilds numpy and re-places onto
whatever mesh/sharding the *restoring* job uses — which is what makes
cross-topology (elastic) restarts work: the checkpoint is topology-free.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover
    zstandard = None

_KIND_ARRAY = 0
_KIND_SCALAR = 1
_KIND_NONE = 2


def _pack_leaf(x) -> dict:
    if x is None:
        return {"k": _KIND_NONE}
    arr = np.asarray(jax.device_get(x))
    if arr.ndim == 0:
        return {
            "k": _KIND_SCALAR,
            "d": arr.dtype.str,
            "v": arr.item() if arr.dtype.kind in "iufb" else arr.tobytes(),
        }
    return {
        "k": _KIND_ARRAY,
        "d": arr.dtype.str,
        "s": list(arr.shape),
        "v": arr.tobytes(),
    }


def _unpack_leaf(rec: dict):
    kind = rec["k"]
    if kind == _KIND_NONE:
        return None
    if kind == _KIND_SCALAR:
        dt = np.dtype(rec["d"])
        v = rec["v"]
        if isinstance(v, (int, float, bool)):
            return np.asarray(v, dtype=dt)
        return np.frombuffer(v, dtype=dt)[0]
    return np.frombuffer(rec["v"], dtype=np.dtype(rec["d"])).reshape(
        rec["s"]
    ).copy()


# Self-describing ("portable") containers: unlike the flat leaves+treedef
# form above, the structure is encoded recursively so a restoring process
# needs no `like` template — required for engine window-state checkpoints
# whose shape (number of retained matrices, per-batch stats rows, ...)
# varies with how far the crashed run got.
_NODE_DICT = "d"
_NODE_LIST = "l"
_NODE_TUPLE = "t"
_NODE_PRIM = "p"   # msgpack-native: str/bytes/bool/int/float, round-trip exact
_NODE_LEAF = "x"   # array/scalar/None via _pack_leaf


def _encode_node(x) -> dict:
    if isinstance(x, dict):
        enc = {}
        for k, v in x.items():
            if not isinstance(k, str):
                raise TypeError(
                    f"portable checkpoints require str dict keys, got {k!r}"
                )
            enc[k] = _encode_node(v)
        return {"t": _NODE_DICT, "v": enc}
    if isinstance(x, (list, tuple)):
        tag = _NODE_TUPLE if isinstance(x, tuple) else _NODE_LIST
        return {"t": tag, "v": [_encode_node(v) for v in x]}
    if isinstance(x, (str, bytes, bool, int, float)) and not isinstance(
        x, np.generic
    ):
        return {"t": _NODE_PRIM, "v": x}
    return {"t": _NODE_LEAF, "v": _pack_leaf(x)}


def _decode_node(rec: dict):
    tag, v = rec["t"], rec["v"]
    if tag == _NODE_DICT:
        return {k: _decode_node(r) for k, r in v.items()}
    if tag == _NODE_LIST:
        return [_decode_node(r) for r in v]
    if tag == _NODE_TUPLE:
        return tuple(_decode_node(r) for r in v)
    if tag == _NODE_PRIM:
        return v
    if tag == _NODE_LEAF:
        return _unpack_leaf(v)
    raise ValueError(f"unknown portable node tag {tag!r}")


def dumps_tree(tree: Any) -> bytes:
    """Portable pytree -> bytes (self-describing, no template needed).

    The wire form of the ``portable=True`` checkpoint encoding, shared by
    the serve protocol frames and the dead-letter/exporter frame logs —
    one encoding for everything that leaves the process.
    """
    return msgpack.packb(_encode_node(tree), use_bin_type=True)


def loads_tree(data: bytes) -> Any:
    """Inverse of ``dumps_tree``."""
    return _decode_node(msgpack.unpackb(data, raw=False))


def save_pytree(tree: Any, path: str | Path, *, compress: bool = True,
                meta: dict | None = None, portable: bool = False) -> None:
    if portable:
        payload = {
            "fmt": "tree",
            "tree": _encode_node(tree),
            "meta": meta or {},
        }
    else:
        leaves, treedef = jax.tree.flatten(tree)
        payload = {
            "leaves": [_pack_leaf(x) for x in leaves],
            "treedef": str(treedef),
            "meta": meta or {},
        }
    raw = msgpack.packb(payload, use_bin_type=True)
    flags = b"\x00"
    if compress and zstandard is not None:
        raw = zstandard.ZstdCompressor(level=3).compress(raw)
        flags = b"\x01"
    Path(path).write_bytes(b"RPCK" + flags + raw)


def load_pytree(path: str | Path, like: Any | None = None):
    """Load; portable files return ``(tree, meta)`` directly. For flat
    files: if ``like`` given, unflatten into its structure (and it must
    match), else return (leaves, treedef_str, meta)."""
    blob = Path(path).read_bytes()
    assert blob[:4] == b"RPCK", "not a repro checkpoint"
    raw = blob[5:]
    if blob[4:5] == b"\x01":
        if zstandard is None:
            raise RuntimeError("zstandard required")
        raw = zstandard.ZstdDecompressor().decompress(raw)
    payload = msgpack.unpackb(raw, raw=False)
    if payload.get("fmt") == "tree":
        return _decode_node(payload["tree"]), payload["meta"]
    leaves = [_unpack_leaf(r) for r in payload["leaves"]]
    if like is not None:
        _, treedef = jax.tree.flatten(like)
        if str(treedef) != payload["treedef"]:
            raise ValueError(
                "checkpoint tree structure mismatch:\n"
                f"  saved: {payload['treedef'][:200]}...\n"
                f"  expected: {str(treedef)[:200]}..."
            )
        return jax.tree.unflatten(treedef, leaves), payload["meta"]
    return leaves, payload["treedef"], payload["meta"]
